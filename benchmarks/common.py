"""Shared benchmark utilities.

Output layout (single-writer rule):

  * every benchmark module writes ONLY under ``benchmarks/results/`` —
    CSVs via ``write_csv``, JSON artifacts via ``write_json``;
  * the repo-root ``BENCH_*.json`` files are the COMMITTED baselines.
    ``benchmarks/run.py`` is their single writer: it promotes a cell's
    ``results/BENCH_*.json`` to the root via ``promote_baseline`` after
    the cell succeeds (full-grid runs only, so CI smoke grids can never
    clobber a committed baseline).
"""
from __future__ import annotations

import contextlib
import csv
import io
import json
import os
import shutil
import sys
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def write_csv(name: str, rows: list[dict]):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    if not rows:
        return path
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return path


def write_json(name: str, obj):
    """Write a JSON artifact under ``benchmarks/results/`` (always)."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def promote_baseline(name: str) -> str | None:
    """Copy ``results/<name>`` to the repo root (the committed baseline).

    ONLY ``benchmarks/run.py`` calls this — the single-writer rule that
    keeps benchmark modules from clobbering committed baselines.
    """
    src = os.path.join(RESULTS_DIR, name)
    if not os.path.exists(src):
        return None
    dst = os.path.join(REPO_ROOT, name)
    shutil.copyfile(src, dst)
    return dst


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
