"""Shared benchmark utilities."""
from __future__ import annotations

import contextlib
import csv
import io
import json
import os
import sys
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def write_csv(name: str, rows: list[dict]):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    if not rows:
        return path
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return path


def write_json(name: str, obj, *, repo_root: bool = False):
    """Write a JSON artifact; ``repo_root=True`` puts it at the repo root
    (committed perf baselines like BENCH_consensus.json live there)."""
    base = REPO_ROOT if repo_root else RESULTS_DIR
    os.makedirs(base, exist_ok=True)
    path = os.path.join(base, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
