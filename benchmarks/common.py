"""Shared benchmark utilities."""
from __future__ import annotations

import contextlib
import csv
import io
import os
import sys
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def write_csv(name: str, rows: list[dict]):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    if not rows:
        return path
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    return path


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
