"""Observability overhead cell: obs-off vs scalar-ring vs node-ring time.

The obs subsystem's whole pitch is "telemetry without a tax": the metrics
ring appends one [n_metrics] f32 row in-jit per round, the per-node ring
appends one [J, n_node_cols] slab next to it, and the host drains only
every K rounds. This cell measures that claim on the CPU debug mesh —
the SAME fused round timed with obs compiled out (``obs=None``), with the
scalar ring only (``with_node_ring=False``), and with the full telemetry
plane — and emits ``BENCH_obs.json`` with two gated scalars
(``check_regression.py``, additive tolerance over committed baselines):
``obs_overhead_ratio`` (full obs vs off, <= 3 points) and
``node_ring_overhead_ratio`` (node ring vs scalar-ring baseline,
<= 3 points — the per-node plane must stay in the noise too).

Measurement discipline: CPU interpret-mode rounds are slow (~100 ms) and
noisy, so the three variants are timed ALTERNATELY round by round (drift
in machine load hits all medians equally), the within-round order rotates
every round (whoever runs later inherits the others' cache pressure —
fixing the order has been observed to bias the ratio by >10 points), and
the per-variant cost is the mean of the LOWEST-QUARTILE round times.
Scheduler interference on a shared runner only ever ADDS time (spikes of
+10 ms on a ~25 ms round are routine), so medians of the variants
inherit independent noise that dwarfs a sub-millisecond ring append; the
low-quartile floor is what the compiled program actually costs. The
host-side drain is timed separately and amortized over its cadence
(``drain_ms / drain_every``) INTO the obs-on cost, so the gate still
covers the full telemetry path, and the cell finishes by writing a
real ObsWriter artifact set under ``results/obs_bench/`` and validating it
(the same well-formedness gate CI runs on launcher drills).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_json

RING_CAP = 64
DRAIN_EVERY = 8
ROUNDS = 96     # quartile floor needs ~24 clean samples per variant; at 32
                # rounds one loaded stretch still swung the ratio 0-4%


def run(rounds: int = ROUNDS) -> dict | None:
    import jax
    if len(jax.devices()) < 8:
        print("obs_overhead: needs 8 devices (run under "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        return None
    from repro.configs import get_reduced_config
    from repro.core.penalty import PenaltyConfig
    from repro.data import DataConfig, SyntheticTokens
    from repro.launch.mesh import make_debug_mesh
    from repro.models import build_model
    from repro.obs import ObsConfig, ObsWriter, validate_obs_dir
    from repro.obs import schema as obs_schema
    from repro.optim import ConsensusConfig, ConsensusTrainer
    from repro.optim.adamw import AdamWConfig

    mesh = make_debug_mesh(multi_pod=True)
    cfg = get_reduced_config("qwen3-4b")
    model = build_model(cfg)
    data = SyntheticTokens(DataConfig(
        vocab=cfg.vocab, seq_len=32, batch_per_node=2, num_nodes=2))

    def make(obs):
        return ConsensusTrainer(
            model, mesh, adamw=AdamWConfig(lr=1e-2),
            consensus=ConsensusConfig(
                penalty=PenaltyConfig(scheme="nap", eta0=0.1),
                topology="ring", local_steps=4, obs=obs))

    tr_off = make(None)
    tr_scalar = make(ObsConfig(ring_capacity=RING_CAP,
                               drain_every=DRAIN_EVERY,
                               with_node_ring=False))
    tr_on = make(ObsConfig(ring_capacity=RING_CAP, drain_every=DRAIN_EVERY))
    st_off = tr_off.init_state(jax.random.PRNGKey(0))
    st_scalar = tr_scalar.init_state(jax.random.PRNGKey(0))
    st_on = tr_on.init_state(jax.random.PRNGKey(0))
    train_off, cons_off = tr_off.jit_step_fns()
    train_scalar, cons_scalar = tr_scalar.jit_step_fns()
    train_on, cons_on = tr_on.jit_step_fns()
    st_off, m = train_off(st_off, data.batch(0))
    jax.block_until_ready(m["loss"])
    st_scalar, m = train_scalar(st_scalar, data.batch(0))
    jax.block_until_ready(m["loss"])
    st_on, m = train_on(st_on, data.batch(0))
    jax.block_until_ready(m["loss"])
    # warm/compile all three rounds before any timing
    st_off, cm = cons_off(st_off, data.batch(0, probe=True))
    jax.block_until_ready(cm["r_max"])
    st_scalar, cm = cons_scalar(st_scalar, data.batch(0, probe=True))
    jax.block_until_ready(cm["r_max"])
    st_on, cm = cons_on(st_on, data.batch(0, probe=True))
    jax.block_until_ready(cm["r_max"])

    import os
    obs_dir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results", "obs_bench")
    writer = ObsWriter(obs_dir, meta={
        "arch": "qwen3-4b (reduced)", "wire_codec": tr_on.codec_name,
        "wire_bytes_per_round":
            tr_on.codec.wire_bytes() * max(len(tr_on.offsets), 1),
        "offsets": [int(o) for o in tr_on.offsets]})
    writer.drain(st_on, step=0)     # flush the warm-up round's ring row
    t_off, t_scalar, t_on, t_drain = [], [], [], []
    n_rows = 0
    for s in range(1, rounds + 1):
        probe = data.batch(s, probe=True)

        def round_off():
            nonlocal st_off
            t0 = time.time()
            st_off, cm = cons_off(st_off, probe)
            jax.block_until_ready(cm["r_max"])
            t_off.append(time.time() - t0)

        def round_scalar():
            nonlocal st_scalar
            t0 = time.time()
            st_scalar, cm = cons_scalar(st_scalar, probe)
            jax.block_until_ready(cm["r_max"])
            t_scalar.append(time.time() - t0)

        def round_on():
            nonlocal st_on, n_rows
            t0 = time.time()
            st_on, cm = cons_on(st_on, probe)
            jax.block_until_ready(cm["r_max"])
            t_on.append(time.time() - t0)
            if s % DRAIN_EVERY == 0:    # timed apart, amortized back in
                t0 = time.time()
                n_rows += writer.drain(st_on, step=s)
                t_drain.append(time.time() - t0)

        # rotate within-round order so no variant always runs cold/hot
        trio = [round_off, round_scalar, round_on]
        for i in range(3):
            trio[(s + i) % 3]()
    n_rows += writer.drain(st_on, step=rounds)      # tail rows
    def low_quartile_mean(ts):
        k = max(1, len(ts) // 4)
        return float(np.mean(np.sort(np.asarray(ts))[:k]))

    low_off = low_quartile_mean(t_off)
    low_scalar = low_quartile_mean(t_scalar)
    low_on = low_quartile_mean(t_on)
    drain_ms = float(np.median(t_drain)) * 1e3 if t_drain else 0.0
    drain_amortized = drain_ms * 1e-3 / DRAIN_EVERY
    # clamped at 0: on a noisy 2-core runner the obs-on floor routinely
    # lands UNDER obs-off; negative "overhead" is noise, not a speedup
    overhead = max(0.0, (low_on + drain_amortized) / max(low_off, 1e-9)
                   - 1.0)
    # the node ring's own marginal cost: full plane vs scalar-ring-only
    # (both pay the append discipline, only one carries the [J, cols] slab)
    node_ring_overhead = max(0.0, low_on / max(low_scalar, 1e-9) - 1.0)
    rollup = writer.finalize()
    report = validate_obs_dir(obs_dir)
    assert report["ok"], f"obs artifact set malformed: {report['errors']}"
    assert n_rows == rounds, (n_rows, rounds)
    assert rollup["dropped_rows"] == 0

    j = tr_on.num_nodes
    bench = {
        "mesh": "2x2x2 (8 fake CPU devices)", "arch": "qwen3-4b (reduced)",
        "rounds": {
            "obs_off": {"round_ms": round(low_off * 1e3, 2)},
            "obs_scalar": {"round_ms": round(low_scalar * 1e3, 2)},
            "obs_on": {"round_ms": round(low_on * 1e3, 2)},
        },
        "obs_overhead_ratio": round(overhead, 4),
        "node_ring_overhead_ratio": round(node_ring_overhead, 4),
        "estimator": f"lowest-quartile mean of {rounds} alternating rounds"
                     " + amortized drain",
        "ring": {"capacity": RING_CAP, "drain_every": DRAIN_EVERY,
                 "columns": obs_schema.NUM_COLUMNS,
                 "ring_hbm_bytes": 4 * RING_CAP * obs_schema.NUM_COLUMNS},
        "node_ring": {"capacity": RING_CAP, "num_nodes": j,
                      "columns": obs_schema.NUM_NODE_COLUMNS,
                      "ring_hbm_bytes":
                          4 * RING_CAP * j * obs_schema.NUM_NODE_COLUMNS},
        "drain": {"rows_drained": n_rows,
                  "drain_ms": round(drain_ms, 3),
                  "dropped": rollup["dropped_rows"],
                  "dropped_node_rows":
                      rollup["per_node"].get("dropped_rows", 0)},
    }
    path = write_json("BENCH_obs.json", bench)
    print(f"obs bench: off {low_off*1e3:.1f}ms scalar {low_scalar*1e3:.1f}ms "
          f"on {low_on*1e3:.1f}ms drain {drain_ms:.2f}ms/{DRAIN_EVERY}r "
          f"overhead {overhead*100:.1f}% node-ring "
          f"{node_ring_overhead*100:.1f}% ({n_rows} rows drained)")
    print(f"wrote {path}")
    return bench


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    args = ap.parse_args()
    run(rounds=args.rounds)
