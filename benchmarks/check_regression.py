"""Benchmark-regression gate: fresh results vs the committed baselines.

Compares freshly written ``benchmarks/results/BENCH_*.json`` artifacts
against the repo-root committed baselines (``BENCH_consensus.json``,
``BENCH_topology.json``, ``BENCH_async.json``, ``BENCH_obs.json``)
with per-metric tolerances,
and exits non-zero when a metric regresses. CI runs it as a step after the
smoke cells; the single report it writes
(``benchmarks/results/regression_report.json``) embeds BOTH the baseline
and the fresh values per checked metric — one diffable artifact to upload
on failure.

Tolerance model (per metric, declared in ``CHECKS`` below):

  * ``ratio``  — fresh may exceed baseline by a multiplicative factor
                 (wall-clock metrics get generous factors: CI machines are
                 noisy; iteration counts get tight ones: they are seeded).
  * ``floor``  — fresh must reach at least ``factor * baseline`` (speedups).
  * ``abs``    — fresh may exceed baseline by an additive slack (fractions).
  * ``exact``  — fresh must equal baseline (byte accounting: wire bytes per
                 round can only change through a deliberate codec/layout
                 change, which must update the committed baseline).

Rows inside a baseline are matched by key fields (topology/scheduler,
wire_frac, round tag); rows present only on one side are reported but not
failed — smoke grids legitimately run a subset of the full baseline grid.
Missing fresh artifacts are skipped (reported), so the gate only checks
what the preceding CI cells actually produced.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks.common import REPO_ROOT, RESULTS_DIR

# metric -> (kind, factor) ; kind in {"ratio", "floor", "abs", "exact"}
_CONSENSUS_ROUND = {
    # wall-clock only catches collapses: a loaded 2-core runner has been
    # observed 2.5x over the committed baseline with no real regression
    "round_ms": ("ratio", 4.0),
    "local_step_ms": ("ratio", 4.0),
    "wire_bytes_per_round": ("exact", 0),
}
CHECKS = {
    "BENCH_consensus.json": {
        "rows_key": "rounds",            # dict tag -> metrics
        "metrics": _CONSENSUS_ROUND,
        # overlap_ratio = pipelined/sequential round time; the committed
        # baseline holds it <= 1.0 (the acceptance cell) and the ratio
        # factor absorbs CPU-runner noise around that anchor — a fresh
        # value drifting far above the baseline means the pipeline's
        # issue phase started COSTING time, which is the regression
        "scalars": {"fused_vs_unfused": ("ratio", 1.5),
                    "overlap_ratio": ("ratio", 1.3)},
    },
    "BENCH_topology.json": {
        "rows_key": "rows",
        "match": ("topology", "scheduler"),
        "metrics": {
            "iters_median": ("ratio", 1.35),
            "active_final": ("abs", 0.2),
            "err_median": ("abs", 5e-3),
        },
        "scalars": {},
    },
    "BENCH_obs.json": {
        "rows_key": "rounds",            # obs_off / obs_on -> round_ms
        "metrics": {"round_ms": ("ratio", 4.0)},
        # THE obs acceptance gates: the metrics ring + spans may cost at
        # most 3 percentage points of round time over the committed
        # baseline overhead (which the full run measures at ~0), and the
        # per-node telemetry ring at most 3 points over the scalar-ring
        # baseline
        "scalars": {"obs_overhead_ratio": ("abs", 0.03),
                    "node_ring_overhead_ratio": ("abs", 0.03)},
    },
    "BENCH_async.json": {
        "rows_key": "rows",
        "match": ("wire_frac",),
        "metrics": {
            # generous floor: smoke runs use a different drop_frac /
            # round budget than the committed full-run baseline, and
            # speedup is a ratio of SMALL integer tick counts (one extra
            # tick swings it ~15%). The benchmark itself already asserts
            # the >=1.3x functional bar; the gate only catches collapses.
            "speedup": ("floor", 0.6),
            "ticks_async": ("ratio", 1.35),
        },
        "scalars": {"objective_drift": ("abs", 0.02)},
    },
}


def _check_metric(name, kind, factor, base, fresh):
    """Returns (ok, detail dict)."""
    ok = True
    if kind == "ratio":
        ok = fresh <= base * factor + 1e-12
    elif kind == "floor":
        ok = fresh >= base * factor - 1e-12
    elif kind == "abs":
        ok = fresh <= base + factor + 1e-12
    elif kind == "exact":
        ok = fresh == base
    else:
        raise ValueError(f"unknown tolerance kind {kind!r} for {name}")
    return ok, {"metric": name, "kind": kind, "factor": factor,
                "baseline": base, "fresh": fresh, "ok": bool(ok)}


def _iter_rows(doc, spec):
    """Yield (row_id, row_dict) for a baseline/fresh document."""
    rows = doc.get(spec["rows_key"], {})
    if isinstance(rows, dict):                   # consensus: tag -> metrics
        for tag, row in rows.items():
            yield tag, row
    else:                                        # list rows matched by key
        for row in rows:
            yield tuple(row.get(k) for k in spec["match"]), row


def check_file(name, *, baseline_dir, results_dir) -> dict:
    """Compare one fresh artifact against its committed baseline."""
    spec = CHECKS[name]
    base_path = os.path.join(baseline_dir, name)
    fresh_path = os.path.join(results_dir, name)
    out = {"name": name, "baseline": base_path, "fresh": fresh_path,
           "checks": [], "unmatched_rows": [], "status": "ok"}
    if not os.path.exists(fresh_path):
        out["status"] = "skipped (no fresh artifact)"
        return out
    if not os.path.exists(base_path):
        out["status"] = "skipped (no committed baseline)"
        return out
    with open(base_path) as f:
        base_doc = json.load(f)
    with open(fresh_path) as f:
        fresh_doc = json.load(f)
    out["baseline_doc"] = base_doc        # both sides ride in the report:
    out["fresh_doc"] = fresh_doc          # ONE diffable failure artifact

    base_rows = dict(_iter_rows(base_doc, spec))
    fresh_rows = dict(_iter_rows(fresh_doc, spec))
    for rid, fresh_row in fresh_rows.items():
        base_row = base_rows.get(rid)
        if base_row is None:
            out["unmatched_rows"].append(str(rid))
            continue
        for metric, (kind, factor) in spec["metrics"].items():
            if metric not in fresh_row or metric not in base_row:
                continue
            ok, detail = _check_metric(metric, kind, factor,
                                       base_row[metric], fresh_row[metric])
            detail["row"] = str(rid)
            out["checks"].append(detail)
    for metric, (kind, factor) in spec["scalars"].items():
        if metric in fresh_doc and metric in base_doc:
            ok, detail = _check_metric(metric, kind, factor,
                                       base_doc[metric], fresh_doc[metric])
            detail["row"] = "<top-level>"
            out["checks"].append(detail)
    if any(not c["ok"] for c in out["checks"]):
        out["status"] = "REGRESSION"
    return out


def run(baseline_dir: str = REPO_ROOT, results_dir: str = RESULTS_DIR,
        names=None) -> dict:
    reports = [check_file(n, baseline_dir=baseline_dir,
                          results_dir=results_dir)
               for n in (names or sorted(CHECKS))]
    n_checked = sum(len(r["checks"]) for r in reports)
    failed = [c for r in reports for c in r["checks"] if not c["ok"]]
    return {"reports": reports, "checks_run": n_checked,
            "failures": failed, "ok": not failed}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default=REPO_ROOT,
                    help="directory of the committed BENCH_*.json baselines")
    ap.add_argument("--results-dir", default=RESULTS_DIR,
                    help="directory of the freshly written artifacts")
    ap.add_argument("--out", default="regression_report.json",
                    help="report name (written under --results-dir)")
    args = ap.parse_args(argv)

    report = run(args.baseline_dir, args.results_dir)
    # write under results/ regardless of where fresh artifacts came from
    os.makedirs(args.results_dir, exist_ok=True)
    path = os.path.join(args.results_dir, args.out)
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")

    for r in report["reports"]:
        print(f"{r['name']}: {r['status']} "
              f"({len(r['checks'])} metrics checked)")
    if not report["ok"]:
        print(f"\nREGRESSIONS ({len(report['failures'])}):")
        for c in report["failures"]:
            print(f"  {c['row']} {c['metric']}: fresh={c['fresh']} vs "
                  f"baseline={c['baseline']} ({c['kind']} {c['factor']})")
        print(f"full diffable report: {path}")
        return 1
    print(f"benchmark-regression gate OK "
          f"({report['checks_run']} metrics); report: {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
