"""Consensus-round overhead microbench (the paper's technique at LM scale).

Measures on the CPU debug mesh: local step time, fused (flat-buffer Pallas
engine) vs unfused (blockwise jnp reference) consensus round time, the
effect of int8 exchange compression, and the communication-volume ratio of
consensus-every-H vs all-reduce-every-step (analytic).

Emits ``BENCH_consensus.json`` under ``benchmarks/results/``; the
repo-root copy is the committed perf baseline tracking round ms, wire
bytes per round and the HBM-pass estimate of the fused engine from PR 1
on, promoted exclusively by ``benchmarks/run.py`` (single-writer rule).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_csv, write_json


def _time_round(cons, state, data, *, rounds: int = 10):
    """Median-of-rounds: CPU interpret-mode rounds are ~1s and noisy."""
    import jax
    state, cm = cons(state, data.batch(0, probe=True))      # warm/compile
    jax.block_until_ready(cm["r_max"])
    times = []
    for s in range(rounds):
        t0 = time.time()
        state, cm = cons(state, data.batch(s, probe=True))
        jax.block_until_ready(cm["r_max"])
        times.append(time.time() - t0)
    return float(np.median(times)), state


def run(steps: int = 6, sharded: bool = False,
        codec: bool = False) -> list[dict]:
    import jax
    if len(jax.devices()) < 8:
        print("consensus_overhead: needs 8 devices "
              "(run under XLA_FLAGS=--xla_force_host_platform_device_count=8)"
              " — reporting analytic numbers only")
        mesh = None
    else:
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(multi_pod=True)

    rows = []
    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.optim import flatten
    cfg = get_reduced_config("qwen3-4b")
    model = build_model(cfg)
    # same wire accounting as the measured rows / dryrun roofline
    ap = model.abstract_params()
    lay0 = flatten.FlatLayout.for_tree(
        ap, block_size=flatten.auto_block_size(ap), node_axis=False)
    params_bytes = lay0.wire_bytes("none")

    for h in (1, 4, 16):
        # cross-pod bytes per step: consensus exchanges deg x params every H
        deg = 1  # ring with J=2
        consensus_bytes = deg * params_bytes / h
        allreduce_bytes = 2 * params_bytes          # ring AR every step
        rows.append({"mode": f"consensus_H{h}", "wire_bytes_per_step":
                     int(consensus_bytes),
                     "vs_allreduce": round(consensus_bytes
                                           / allreduce_bytes, 4)})
    rows.append({"mode": "allreduce_every_step",
                 "wire_bytes_per_step": int(allreduce_bytes),
                 "vs_allreduce": 1.0})

    bench = {"mesh": "2x2x2 (8 fake CPU devices)" if mesh is not None
             else "analytic-only", "arch": "qwen3-4b (reduced)",
             "rounds": {}}
    if mesh is not None:
        from repro.core.penalty import PenaltyConfig
        from repro.data import DataConfig, SyntheticTokens
        from repro.launch.dryrun import fused_round_roofline
        from repro.optim import ConsensusConfig, ConsensusTrainer
        from repro.optim.adamw import AdamWConfig
        data = SyntheticTokens(DataConfig(
            vocab=cfg.vocab, seq_len=32, batch_per_node=2, num_nodes=2))
        for compression in ("none", "int8"):
            t_local = None          # train_step is fused-flag independent:
            for fused in (True, False):     # time it once per compression
                tr = ConsensusTrainer(
                    model, mesh, adamw=AdamWConfig(lr=1e-2),
                    consensus=ConsensusConfig(
                        penalty=PenaltyConfig(scheme="nap", eta0=0.1),
                        topology="ring", local_steps=4,
                        compression=compression, use_fused_kernel=fused))
                state = tr.init_state(jax.random.PRNGKey(0))
                train, cons = tr.jit_step_fns()
                state, m = train(state, data.batch(0))          # warm
                if t_local is None:
                    t0 = time.time()
                    for s in range(steps):
                        state, m = train(state, data.batch(s))
                    jax.block_until_ready(m["loss"])
                    t_local = (time.time() - t0) / steps
                t_cons, state = _time_round(cons, state, data)
                tag = f"{'fused' if fused else 'unfused'}_{compression}"
                # per node per round, summed over graph offsets — the same
                # accounting the dryrun roofline uses
                wire_bytes = len(tr.offsets) * tr.layout.wire_bytes(
                    compression)
                rows.append({"mode": f"measured_{tag}",
                             "wire_bytes_per_step": wire_bytes,
                             "vs_allreduce": round(t_cons
                                                   / max(t_local, 1e-9), 3)})
                bench["rounds"][tag] = {
                    "round_ms": round(t_cons * 1e3, 2),
                    "local_step_ms": round(t_local * 1e3, 2),
                    "wire_bytes_per_round": wire_bytes,
                }
                print(f"consensus bench ({tag}): local {t_local*1e3:.1f}ms "
                      f"round {t_cons*1e3:.1f}ms")
        if sharded:
            # sharded-engine cell (--sharded): measured sharded fused
            # rounds plus the per-device consensus-state HBM report the
            # CI job uploads as an artifact
            hbm_report = {"mesh": bench["mesh"], "arch": bench["arch"],
                          "compressions": {}}
            for compression in ("none", "int8"):
                tr = ConsensusTrainer(
                    model, mesh, adamw=AdamWConfig(lr=1e-2),
                    consensus=ConsensusConfig(
                        penalty=PenaltyConfig(scheme="nap", eta0=0.1),
                        topology="ring", local_steps=4,
                        compression=compression, shard_consensus=True))
                state = tr.init_state(jax.random.PRNGKey(0))
                train, cons = tr.jit_step_fns()
                state, m = train(state, data.batch(0))          # warm
                t0 = time.time()
                for s in range(steps):      # own local-step measurement —
                    state, m = train(state, data.batch(s))  # no reuse of
                jax.block_until_ready(m["loss"])            # earlier cells
                t_local_sh = (time.time() - t0) / steps
                t_cons, state = _time_round(cons, state, data)
                wire_bytes = len(tr.offsets) * tr.slayout.wire_bytes(
                    compression)
                rows.append({"mode": f"measured_sharded_{compression}",
                             "wire_bytes_per_step": wire_bytes,
                             "vs_allreduce": round(
                                 t_cons / max(t_local_sh, 1e-9), 3)})
                bench["rounds"][f"sharded_{compression}"] = {
                    "round_ms": round(t_cons * 1e3, 2),
                    "local_step_ms": round(t_local_sh * 1e3, 2),
                    "wire_bytes_per_round": wire_bytes,
                }
                print(f"consensus bench (sharded_{compression}): "
                      f"round {t_cons*1e3:.1f}ms")
                hbm_report["compressions"][compression] = \
                    fused_round_roofline(
                        model, mesh, compression=compression,
                        shard_consensus=True,
                        with_ledger=True)["consensus_state"]
            state_rep = hbm_report["compressions"]["none"]
            hbm_report["shrink_factor"] = round(
                state_rep["per_device_unsharded"]["total"]
                / max(state_rep["per_device"]["total"], 1), 2)
            path = write_json("consensus_hbm_report.json", hbm_report)
            print(f"wrote {path} (per-device consensus-state shrink = "
                  f"{hbm_report['shrink_factor']}x)")
            bench["hbm_report"] = hbm_report
        if codec:
            # wire-codec cell (--codec): one measured fused round per codec
            # plus the per-codec wire-bytes report the CI codec lane
            # uploads as an artifact (all sizes read from repro.wire)
            from repro import wire as wire_lib
            codec_report = {"mesh": bench["mesh"], "arch": bench["arch"],
                            "codecs": {}}
            for name in wire_lib.WIRE_CODECS:
                tr = ConsensusTrainer(
                    model, mesh, adamw=AdamWConfig(lr=1e-2),
                    consensus=ConsensusConfig(
                        penalty=PenaltyConfig(scheme="nap", eta0=0.1),
                        topology="ring", local_steps=4, wire_codec=name))
                state = tr.init_state(jax.random.PRNGKey(0))
                train, cons = tr.jit_step_fns()
                state, m = train(state, data.batch(0))          # warm
                t_cons, state = _time_round(cons, state, data)
                wire_bytes = len(tr.offsets) * tr.codec.wire_bytes()
                spec = tr.codec.kernel_dequant_spec()
                rows.append({"mode": f"measured_codec_{name}",
                             "wire_bytes_per_step": wire_bytes,
                             "vs_allreduce": round(
                                 wire_bytes / max(allreduce_bytes, 1), 4)})
                codec_report["codecs"][name] = {
                    "round_ms": round(t_cons * 1e3, 2),
                    "wire_bytes_per_round": wire_bytes,
                    "wire_bytes_per_param": round(
                        tr.codec.wire_bytes() / tr.layout.total, 4),
                    "scale_granularity": ("block" if spec.per_block
                                          else "leaf"),
                    "scale_width": spec.scale_width,
                    "roofline": fused_round_roofline(model, mesh,
                                                     compression=name),
                }
                print(f"consensus bench (codec {name}): "
                      f"round {t_cons*1e3:.1f}ms wire {wire_bytes}B")
            native_b = codec_report["codecs"]["native"][
                "wire_bytes_per_round"]
            for name, rec in codec_report["codecs"].items():
                rec["wire_vs_native"] = round(
                    rec["wire_bytes_per_round"] / max(native_b, 1), 4)
            path = write_json("wire_codec_report.json", codec_report)
            print(f"wrote {path}")
            bench["codec_report"] = codec_report
        # overlap cell: latency-hiding round pipeline, measured on a
        # 4-pod mesh (ring offsets [1, 3] — depth > 1 is real, unlike the
        # J=2 debug mesh's single offset). overlap_on issues every
        # offset's collective-permute up front (pipeline_offsets=4);
        # overlap_off is the sequential issue-consume loop. Both compute
        # bit-identical rounds, so the ratio isolates pure scheduling.
        from repro.launch.mesh import make_mesh
        mesh4 = make_mesh((4, 2, 1), ("pod", "data", "model"))
        data4 = SyntheticTokens(DataConfig(
            vocab=cfg.vocab, seq_len=32, batch_per_node=2, num_nodes=4))
        overlap_s = {}
        for pipe, tag in ((1, "overlap_off"), (4, "overlap_on")):
            tr = ConsensusTrainer(
                model, mesh4, adamw=AdamWConfig(lr=1e-2),
                consensus=ConsensusConfig(
                    penalty=PenaltyConfig(scheme="nap", eta0=0.1),
                    topology="ring", local_steps=4, wire_codec="int8",
                    pipeline_offsets=pipe))
            state = tr.init_state(jax.random.PRNGKey(0))
            train, cons = tr.jit_step_fns()
            state, m = train(state, data4.batch(0))         # warm
            t_cons, state = _time_round(cons, state, data4)
            wire_bytes = len(tr.offsets) * tr.codec.wire_bytes()
            overlap_s[tag] = t_cons
            rows.append({"mode": f"measured_{tag}",
                         "wire_bytes_per_step": wire_bytes,
                         "vs_allreduce": round(
                             wire_bytes / max(allreduce_bytes, 1), 4)})
            bench["rounds"][tag] = {
                "round_ms": round(t_cons * 1e3, 2),
                "wire_bytes_per_round": wire_bytes,
            }
            print(f"consensus bench ({tag}): round {t_cons*1e3:.1f}ms")
        bench["overlap_ratio"] = round(
            overlap_s["overlap_on"] / max(overlap_s["overlap_off"], 1e-9),
            3)
        print(f"overlap ratio (pipelined/sequential) = "
              f"{bench['overlap_ratio']}")
        bench["fused_round_model"] = {
            comp: fused_round_roofline(model, mesh, compression=comp)
            for comp in ("none", "int8")}
        f_ms = bench["rounds"]["fused_none"]["round_ms"]
        u_ms = bench["rounds"]["unfused_none"]["round_ms"]
        bench["fused_vs_unfused"] = round(f_ms / max(u_ms, 1e-9), 3)
        # results/ only — run.py promotes to the committed root baseline
        path = write_json("BENCH_consensus.json", bench)
        print(f"wrote {path} (fused/unfused = {bench['fused_vs_unfused']})")
    write_csv("consensus_overhead.csv", rows)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--sharded", action="store_true",
                    help="add the sharded-engine cell (measured sharded "
                         "rounds + per-device consensus-state HBM report)")
    ap.add_argument("--codec", action="store_true",
                    help="add the wire-codec cell: one measured fused "
                         "round per codec (native/int8/fp8_e4m3/fp8_e5m2) "
                         "+ the per-codec wire-bytes report "
                         "(results/wire_codec_report.json)")
    args = ap.parse_args()
    run(sharded=args.sharded, codec=args.codec)
