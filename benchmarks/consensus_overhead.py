"""Consensus-round overhead microbench (the paper's technique at LM scale).

Measures on the CPU debug mesh: local step time, consensus round time, the
effect of int8 exchange compression, and the communication-volume ratio of
consensus-every-H vs all-reduce-every-step (analytic).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import write_csv


def run(steps: int = 6) -> list[dict]:
    import jax
    if len(jax.devices()) < 8:
        print("consensus_overhead: needs 8 devices "
              "(run under XLA_FLAGS=--xla_force_host_platform_device_count=8)"
              " — reporting analytic numbers only")
        mesh = None
    else:
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(multi_pod=True)

    rows = []
    from repro.configs import get_reduced_config
    from repro.models import build_model
    cfg = get_reduced_config("qwen3-4b")
    model = build_model(cfg)
    params_bytes = model.param_count() * 2  # bf16 wire

    for h in (1, 4, 16):
        # cross-pod bytes per step: consensus exchanges deg x params every H
        deg = 1  # ring with J=2
        consensus_bytes = deg * params_bytes / h
        allreduce_bytes = 2 * params_bytes          # ring AR every step
        rows.append({"mode": f"consensus_H{h}", "wire_bytes_per_step":
                     int(consensus_bytes),
                     "vs_allreduce": round(consensus_bytes
                                           / allreduce_bytes, 4)})
    rows.append({"mode": "allreduce_every_step",
                 "wire_bytes_per_step": int(allreduce_bytes),
                 "vs_allreduce": 1.0})

    if mesh is not None:
        import jax.numpy as jnp
        from repro.core.penalty import PenaltyConfig
        from repro.data import DataConfig, SyntheticTokens
        from repro.optim import ConsensusConfig, ConsensusTrainer
        from repro.optim.adamw import AdamWConfig
        for compression in ("none", "int8"):
            tr = ConsensusTrainer(
                model, mesh, adamw=AdamWConfig(lr=1e-2),
                consensus=ConsensusConfig(
                    penalty=PenaltyConfig(scheme="nap", eta0=0.1),
                    topology="ring", local_steps=4,
                    compression=compression))
            state = tr.init_state(jax.random.PRNGKey(0))
            data = SyntheticTokens(DataConfig(
                vocab=cfg.vocab, seq_len=32, batch_per_node=2, num_nodes=2))
            train = jax.jit(tr.train_step)
            cons = jax.jit(tr.consensus_step)
            state, _ = train(state, data.batch(0))          # warm
            state, _ = cons(state, data.batch(0, probe=True))
            t0 = time.time()
            for s in range(steps):
                state, m = train(state, data.batch(s))
            jax.block_until_ready(m["loss"])
            t_local = (time.time() - t0) / steps
            t0 = time.time()
            for s in range(3):
                state, cm = cons(state, data.batch(s, probe=True))
            jax.block_until_ready(cm["r_max"])
            t_cons = (time.time() - t0) / 3
            rows.append({"mode": f"measured_{compression}",
                         "wire_bytes_per_step": int(params_bytes),
                         "vs_allreduce": round(t_cons / max(t_local, 1e-9),
                                               3)})
            print(f"consensus bench ({compression}): local "
                  f"{t_local*1e3:.1f}ms round {t_cons*1e3:.1f}ms")
    write_csv("consensus_overhead.csv", rows)
    return rows


if __name__ == "__main__":
    run()
