"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Each VARIANT is a named set of knobs applied to one (arch x shape x mesh)
cell; the cell is re-lowered and the roofline terms recorded next to the
baseline in perf_results.json. Run AFTER the baseline dry-run:

  PYTHONPATH=src python -m benchmarks.perf_iter --cell A --variant a1
  PYTHONPATH=src python -m benchmarks.perf_iter --cell all   # everything
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402

CELLS = {
    # most collective-bound baseline
    "A": ("rwkv6-7b", "train_4k", False),
    # worst useful-FLOPs fraction / memory blow-up
    "B": ("qwen2-7b", "prefill_32k", False),
    # the paper's own technique on the multi-pod mesh
    "C": ("qwen3-4b", "train_4k", True),
}

# variant -> (description, knob dict)
VARIANTS = {
    "A": [
        ("a1_chunked_wkv",
         "chunk the WKV recurrence (C=64): turns 4096 sequential outer "
         "products into 64 matmul chunks; predicted: collective term down "
         "~C-fold if per-step collectives existed, compute term down via "
         "MXU-shaped ops",
         {"rwkv_chunk": 64}),
        ("a2_chunked_plus_grad_rs",
         "a1 + reduce-scatter gradients into FSDP shards instead of "
         "all-reduce: predicted ~2x less gradient wire traffic",
         {"rwkv_chunk": 64, "grad_rs": True}),
        ("a3_chunk128",
         "larger WKV chunk (C=128): fewer scan trips, bigger matmuls; "
         "predicted small further compute-term win, VMEM pressure up",
         {"rwkv_chunk": 128, "grad_rs": True}),
        ("a5_lora_replicated",
         "HLO shows ~53GB/layer of activation all-reduces — far beyond the "
         "2 legit TP psums. The [D,rank] ddlerp/decay LoRA weights are FSDP-"
         "sharded on 'data', so their [B,S,D] products carry D-on-data "
         "sharding conflicting with batch-on-data => per-layer full-"
         "activation reshards. Replicate the (256KB) LoRAs: predicted "
         "multi-fold collective-term drop",
         {"rwkv_chunk": 64, "grad_rs": True, "lora_replicated": True,
          "psum_bf16": True}),
        ("a4_psum_bf16",
         "HLO inspection showed the dominant per-layer collective is an f32 "
         "[B,S,D] activation all-reduce after the row-parallel projections; "
         "force bf16 psum wire via preferred_element_type: predicted ~2x "
         "drop of that share",
         {"rwkv_chunk": 64, "grad_rs": True, "psum_bf16": True}),
    ],
    "B": [
        ("b1_serial_chunks",
         "serialize attention query chunks with optimization_barrier: "
         "predicted peak temp memory ~#chunks-fold down (264GB -> <20GB), "
         "traffic unchanged",
         {"serial_chunks": True}),
        ("b2_serial_plus_bf16probs",
         "b1 + bf16 attention probs: predicted ~2x less attention HBM "
         "traffic (the dominant memory term)",
         {"serial_chunks": True, "probs_bf16": True}),
        ("b3_smaller_chunks",
         "b2 + 512-query chunks: smaller live logits tiles; predicted "
         "further peak reduction, slight HLO growth",
         {"serial_chunks": True, "probs_bf16": True, "attn_chunk": 512}),
        ("b4_pad_heads",
         "root cause of the 247GB/dev peak: 28 heads do not divide TP=16 so "
         "attention is REPLICATED over the model axis; pad Q heads to 32 "
         "(zero out-proj rows, numerically exact): predicted ~16x less "
         "attention memory + the memory term down several-fold for +14% "
         "attention FLOPs",
         {"serial_chunks": True, "probs_bf16": True, "pad_heads": 16}),
    ],
    "C": [
        ("c1_int8_exchange",
         "int8-quantize the consensus parameter exchange: predicted ~2x "
         "less cross-pod (collective-permute) wire bytes vs bf16",
         {"compression": "int8"}),
        ("c2_int8_plus_grad_rs",
         "c1 + reduce-scatter local gradients: predicted large drop in the "
         "within-pod all-reduce share of the consensus-train collective",
         {"compression": "int8", "grad_rs": True}),
        ("c3_small_probe",
         "c1 revealed the round's wire is dominated by the objective-probe "
         "forwards (per-layer TP psums), not the exchange; probe kappa on "
         "1/8 of the batch (eq. 7 only needs a noisy objective ranking): "
         "predicted ~8x drop of the probe share => round wire ~12GB",
         {"compression": "int8", "probe_frac": 8}),
    ],
}


def apply_knobs(knobs: dict):
    from repro.launch import dryrun
    from repro.models import attention as at
    from repro.models import rwkv6 as rw
    at.SERIAL_CHUNKS = knobs.get("serial_chunks", False)
    at.PROBS_BF16 = knobs.get("probs_bf16", False)
    at.ATTN_CHUNK = knobs.get("attn_chunk", 1024)
    rw.TIME_CHUNK = knobs.get("rwkv_chunk", 0)
    rw.PSUM_BF16 = knobs.get("psum_bf16", False)
    at.PAD_HEADS_MULT = knobs.get("pad_heads", 0)
    rw.LORA_REPLICATED = knobs.get("lora_replicated", False)
    dryrun.KNOBS["grad_rs"] = knobs.get("grad_rs", False)
    dryrun.KNOBS["compression"] = knobs.get("compression", "none")
    dryrun.KNOBS["wire_codec"] = knobs.get("wire_codec", "")
    dryrun.KNOBS["probe_frac"] = knobs.get("probe_frac", 1)


def run_variant(cell_key: str, name: str, desc: str, knobs: dict) -> dict:
    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import lower_cell
    arch, shape, multi = CELLS[cell_key]
    apply_knobs(knobs)
    try:
        t0 = time.time()
        rec = lower_cell(get_config(arch), SHAPES[shape], multi_pod=multi)
        rec.update({"variant": name, "cell": cell_key, "hypothesis": desc,
                    "knobs": knobs, "wall_s": round(time.time() - t0, 1)})
    finally:
        apply_knobs({})
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all", choices=["A", "B", "C", "all"])
    ap.add_argument("--variant", default="all")
    ap.add_argument("--out", default="perf_results.json")
    args = ap.parse_args(argv)

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {r["variant"] for r in results if "error" not in r}

    cells = ["A", "B", "C"] if args.cell == "all" else [args.cell]
    for ck in cells:
        for name, desc, knobs in VARIANTS[ck]:
            if args.variant != "all" and name != args.variant:
                continue
            if name in done:
                continue
            print(f"=== variant {name}: {desc[:70]}", flush=True)
            try:
                rec = run_variant(ck, name, desc, knobs)
                rl = rec["roofline"]
                print(f"    dom={rl['dominant']} comp={rl['compute_s']:.3f} "
                      f"mem={rl['memory_s']:.3f} coll={rl['collective_s']:.3f}",
                      flush=True)
            except Exception as e:  # noqa: BLE001
                import traceback
                traceback.print_exc()
                rec = {"variant": name, "cell": ck, "error": str(e)[:1500]}
            results.append(rec)
            with open(args.out + ".tmp", "w") as f:
                json.dump(results, f, indent=1)
            os.replace(args.out + ".tmp", args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
