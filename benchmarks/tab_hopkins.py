"""Paper §5.2 Hopkins-155-style table: mean iterations over many objects.

The Hopkins dataset is unavailable offline; we generate a population of
synthetic rigid objects with varying frame/point counts and noise (the
quantity the paper reports is the RELATIVE speedup of each scheme vs the
fixed-eta baseline, which survives the data swap). Objects whose
reconstruction error exceeds 15 degrees are excluded from the mean, matching
the paper's protocol.
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import write_csv


def run(num_objects: int = 8, seeds: int = 2, max_iters: int = 300
        ) -> list[dict]:
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.core import PenaltyConfig, build_graph
    from repro.ppca import DPPCA, fit_svd, max_subspace_angle, turntable_sfm

    schemes = ("fixed", "vp", "ap", "nap", "vp_ap", "vp_nap")
    rows = []
    for topo in ("complete", "ring"):
        g = build_graph(topo, 5)
        mean_iters = {s: [] for s in schemes}
        for obj in range(num_objects):
            rng = np.random.default_rng(obj)
            frames = int(rng.choice([20, 30, 40]))
            points = int(rng.integers(40, 120))
            sfm = turntable_sfm(num_cameras=5, frames=frames, points=points,
                                noise_std=float(rng.uniform(0.005, 0.02)),
                                seed=1000 + obj)
            x = jnp.asarray(sfm.x_nodes)
            ref = fit_svd(jnp.asarray(sfm.measurements), 3)
            for scheme in schemes:
                its = []
                for s in range(seeds):
                    eng = DPPCA(latent_dim=3, graph=g,
                                penalty_cfg=PenaltyConfig(scheme=scheme,
                                                          eta0=10.0))
                    st = eng.init(jax.random.PRNGKey(s), x)
                    st, hist = eng.run(st, x, max_iters=max_iters,
                                       rel_tol=1e-3, min_iters=10)
                    ang = float(max_subspace_angle(st.W, ref.W))
                    if ang <= 15.0:       # paper's exclusion rule
                        its.append(hist["iterations"])
                if its:
                    mean_iters[scheme].append(float(np.mean(its)))
        base = np.mean(mean_iters["fixed"]) if mean_iters["fixed"] else 1.0
        for scheme in schemes:
            mi = float(np.mean(mean_iters[scheme])) if mean_iters[scheme] \
                else float("nan")
            speedup = 100.0 * (base - mi) / base
            rows.append({"topology": topo, "scheme": scheme,
                         "mean_iters": round(mi, 1),
                         "speedup_vs_fixed_pct": round(speedup, 1),
                         "objects": len(mean_iters[scheme])})
            print(f"hopkins-style {topo:8s} {scheme:7s} iters={mi:6.1f} "
                  f"speedup={speedup:5.1f}%", flush=True)
    write_csv("tab_hopkins.csv", rows)
    return rows


if __name__ == "__main__":
    run()
