"""Beyond-paper ablation: penalty schemes on LM consensus training.

Trains the reduced qwen3 config across 2 simulated pods with each penalty
scheme and reports loss after N steps + replica divergence — the paper's
D-PPCA comparison transplanted to the LM trainer. Needs 8 devices.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import write_csv


def run(steps: int = 16, local_steps: int = 2) -> list[dict]:
    import jax
    if len(jax.devices()) < 8:
        print("lm_scheme_ablation: needs XLA_FLAGS="
              "--xla_force_host_platform_device_count=8; skipping")
        return []
    import jax.numpy as jnp
    from repro.configs import get_reduced_config
    from repro.core.penalty import PenaltyConfig, SCHEMES
    from repro.data import DataConfig, SyntheticTokens
    from repro.launch.mesh import make_debug_mesh
    from repro.models import build_model
    from repro.optim import ConsensusConfig, ConsensusTrainer
    from repro.optim.adamw import AdamWConfig

    mesh = make_debug_mesh(multi_pod=True)
    cfg = get_reduced_config("qwen3-4b")
    model = build_model(cfg)
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      batch_per_node=4, num_nodes=2))
    rows = []
    for scheme in SCHEMES:
        tr = ConsensusTrainer(
            model, mesh, adamw=AdamWConfig(lr=1e-2),
            consensus=ConsensusConfig(
                penalty=PenaltyConfig(scheme=scheme, eta0=0.1),
                topology="ring", local_steps=local_steps))
        state = tr.init_state(jax.random.PRNGKey(0))
        train = jax.jit(tr.train_step)
        cons = jax.jit(tr.consensus_step)
        losses = []
        for step in range(steps):
            state, m = train(state, data.batch(step))
            losses.append(float(m["loss"]))
            if tr.should_sync(step):
                state, cm = cons(state, data.batch(step, probe=True))
        leaf = jax.tree_util.tree_leaves(state.params)[0]
        div = float(jnp.abs(leaf[0] - leaf[1]).max())
        rows.append({"scheme": scheme,
                     "final_loss": round(losses[-1], 4),
                     "mean_last4": round(float(np.mean(losses[-4:])), 4),
                     "replica_divergence": round(div, 5),
                     "eta_mean": round(float(cm["eta_mean"]), 4)})
        print(f"lm_ablation {scheme:8s} loss={losses[-1]:.4f} "
              f"div={div:.5f}", flush=True)
    write_csv("lm_scheme_ablation.csv", rows)
    return rows


if __name__ == "__main__":
    run()
