"""Straggler benchmark: bounded-staleness executor vs the sync engine.

Setup (8 fake devices, J=4 pods, reduced LM): diverge the node replicas
with a few local optimizer steps, then run PURE consensus rounds until one
COMMON absolute residual bar (``drop_frac`` x the starting r_max — the §5
stop-criterion idea applied to the quantity the rounds drive, with the
same bar for both executors) under an injected 2x-slow node:

  * sync    — every round barriers on the slow node AND serializes the
              exchange: ``round_s = max(compute) + wire``;
  * async   — bounded staleness ``N``: the fleet ticks at the fast nodes'
              cadence, permutes double-buffer behind compute, and the slow
              node's payloads land a round late (its rows advance at its
              own rate via the executor's ``advance`` mask).

The NUMERICS are real (stale payloads feed the fused kernel; the final
objective is measured, not modeled). The WALL-CLOCK is the ``RoundClock``
event model with stated constants: fast-node round time = 1 unit,
straggler = ``factor`` units, wire = ``wire_frac`` units (0.5 = the
LM-scale regime where a full-parameter DCN exchange costs half the local
phase — see ``fused_round_roofline``; a wire_frac=0 row is reported too so
the barrier-only effect is visible). The async side is RE-SIMULATED per
wire point with the clock carrying that latency, so the arrival dynamics
the tick count reflects are the same ones the wall-clock model prices.

Acceptance (asserted in ``main``): >= 1.3x modeled wall-clock speedup at
wire_frac 0.5 with the final objective unchanged within 2%.

Writes ``BENCH_async.json`` under ``benchmarks/results/``;
``benchmarks/run.py --full`` promotes it to the committed root baseline.
Needs 8 devices — run via ``benchmarks/run.py --only async`` or with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

import argparse

import numpy as np


def _build(j, async_cfg, scheduler, max_staleness):
    from repro.configs import get_reduced_config
    from repro.core.penalty import PenaltyConfig
    from repro.data import DataConfig, SyntheticTokens
    from repro.launch.mesh import make_mesh
    from repro.models import build_model
    from repro.optim import ConsensusConfig, ConsensusTrainer
    from repro.optim.adamw import AdamWConfig
    from repro.topology import TopologyConfig

    mesh = make_mesh((j, 2, 1), ("pod", "data", "model"))
    cfg = get_reduced_config("qwen3-4b")
    model = build_model(cfg)
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      batch_per_node=2, num_nodes=j))
    trainer = ConsensusTrainer(
        model, mesh, adamw=AdamWConfig(lr=1e-2),
        consensus=ConsensusConfig(
            penalty=PenaltyConfig(scheme="nap", eta0=0.1),
            topology="ring", local_steps=1,
            dyn_topology=TopologyConfig(scheduler=scheduler,
                                        max_staleness=max_staleness),
            async_exec=async_cfg))
    return trainer, data


def _diverge(trainer, data, diverge_steps, seed=0):
    import jax
    state = trainer.init_state(jax.random.PRNGKey(seed))
    train = jax.jit(trainer.train_step)
    for s in range(diverge_steps):
        state, _ = train(state, data.batch(s))
    return state


def _run_until(step_round, state, probe, *, target, max_rounds):
    """Rounds until the consensus residual r_max drops to ``target``.

    One common ABSOLUTE residual bar for both executors (sync sets it from
    its own start) — "rounds to the same consensus progress", immune to
    stop-criterion asymmetries between the two metrics streams. The async
    r_max covers ADVANCING nodes only, so two consecutive sub-target ticks
    are required: with a 2x straggler that spans both fleet phases, i.e.
    the laggard's own row has cleared the bar too.
    """
    hist = []
    below = 0
    for r in range(max_rounds):
        state, m = step_round(state, probe)
        hist.append((float(m["r_max"]), float(m["f_mean"])))
        below = below + 1 if hist[-1][0] <= target else 0
        if below >= 2:
            return state, r + 1, hist
    return state, max_rounds, hist


def run(*, smoke: bool = False, j: int = 4, factor: float = 2.0,
        max_staleness: int = 2, diverge_steps: int = 4,
        wire_fracs=(0.0, 0.5), drop_frac: float = 0.5,
        max_rounds: int = 60) -> dict:
    import jax
    from benchmarks.common import write_csv, write_json
    from repro.async_exec import (AsyncConfig, AsyncExecutor, RoundClock,
                                  straggler_compute)

    if smoke:
        max_rounds, drop_frac = 40, 0.6

    # ---- sync reference (barrier executor) -----------------------------
    tr_sync, data = _build(j, None, "static", max_staleness)
    probe = data.batch(0, probe=True)
    state = _diverge(tr_sync, data, diverge_steps)
    _, cons = tr_sync.jit_step_fns()
    # one throwaway probe round (undonated jit) sets the common residual bar
    _, m0 = jax.jit(tr_sync.consensus_step)(state, probe)
    target = drop_frac * float(m0["r_max"])
    state_s, rounds_sync, hist_s = _run_until(
        lambda s, p: cons(s, p), state, probe,
        target=target, max_rounds=max_rounds)
    f_sync, r_sync0, r_syncF = hist_s[-1][1], hist_s[0][0], hist_s[-1][0]

    # ---- async with an injected straggler: ONE RUN PER WIRE POINT ------
    # the clock carries the wire latency it prices — arrivals at wf=0.5
    # really land half a round late, so the staleness dynamics (and the
    # tick count) are faithful to the wall-clock model, not optimistic
    rows = []
    drifts, r_finals, rounds_done = {}, {}, {}
    for wf in wire_fracs:
        tr_async, data = _build(j, AsyncConfig(max_staleness=max_staleness),
                                "stale", max_staleness)
        state = _diverge(tr_async, data, diverge_steps)
        clock = RoundClock(
            compute_s=straggler_compute(j, base_s=1.0, factor=factor),
            wire_s=wf, offsets=tuple(tr_async.offsets))
        ex = AsyncExecutor(tr_async, clock)
        state_a, ticks_async, hist_a = _run_until(
            ex.consensus_round, state, probe,
            target=target, max_rounds=max_rounds)
        f_async, r_finals[wf] = hist_a[-1][1], hist_a[-1][0]
        drifts[wf] = abs(f_async - f_sync) / (abs(f_sync) + 1e-12)
        rounds_done[wf] = ex.summary()["rounds_done"]
        sync_round_s = factor + wf            # barrier + serialized wire
        async_tick_s = 1.0                    # wire double-buffered away
        wall_sync = rounds_sync * sync_round_s
        wall_async = ticks_async * async_tick_s
        rows.append({
            "wire_frac": wf, "factor": factor,
            "rounds_sync": rounds_sync, "ticks_async": ticks_async,
            "wall_sync": round(wall_sync, 3),
            "wall_async": round(wall_async, 3),
            "speedup": round(wall_sync / max(wall_async, 1e-9), 3),
            "f_async": round(f_async, 6),
        })
        print(f"async_staleness wire_frac={wf:.2f} "
              f"sync={rounds_sync}r x {sync_round_s:.2f} "
              f"async={ticks_async}t x {async_tick_s:.2f} "
              f"speedup={rows[-1]['speedup']:.2f}x "
              f"drift={drifts[wf]:.3%}", flush=True)

    obj_drift = max(drifts.values())
    bench = {
        "j": j, "factor": factor, "max_staleness": max_staleness,
        "smoke": smoke, "drop_frac": drop_frac,
        "r_target": round(target, 4),
        "f_sync": round(f_sync, 6),
        "objective_drift": round(obj_drift, 6),
        "r_start": round(r_sync0, 4),
        "r_final_sync": round(r_syncF, 4),
        "r_final_async": {str(k): round(v, 4) for k, v in r_finals.items()},
        "straggler_rounds_done": {str(k): v
                                  for k, v in rounds_done.items()},
        "rows": rows,
    }
    write_csv("async_staleness.csv", rows)
    write_json("BENCH_async.json", bench)
    print(f"async_staleness: f_sync={f_sync:.4f} "
          f"max_drift={obj_drift:.3%}", flush=True)
    return bench


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced caps for CI")
    ap.add_argument("--factor", type=float, default=2.0)
    ap.add_argument("--max-staleness", type=int, default=2)
    args = ap.parse_args(argv)
    bench = run(smoke=args.smoke, factor=args.factor,
                max_staleness=args.max_staleness)
    # acceptance: >=1.3x at the LM-scale wire point, objective unchanged
    by_wf = {r["wire_frac"]: r for r in bench["rows"]}
    assert by_wf[0.5]["speedup"] >= 1.3, by_wf
    assert bench["objective_drift"] < 0.02, bench
    print("async_staleness: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
