"""Topology-dynamics sweep: scheduler x topology on the synthetic problem.

For every scheduler (static, budget, random, round_robin) x topology
(complete, ring, cluster, expander) at J=12, runs the dense consensus-ADMM
engine (NAP penalties) on the synthetic least-squares problem and records

  * iterations to the paper's §5 relative-objective criterion,
  * final max parameter error vs the centralized solution,
  * mean active-edge fraction over the run and the final fraction after
    100 post-convergence epochs (the budget scheduler's §4 shedding).

Writes ``BENCH_topology.json`` under ``benchmarks/results/`` plus the
usual results CSV; ``benchmarks/run.py --full`` promotes it to the
repo-root committed baseline (single-writer rule, see
``benchmarks/common.py``). ``--smoke`` runs a reduced grid for CI.
"""
from __future__ import annotations

import argparse

import numpy as np

TOPOLOGIES = ("complete", "ring", "cluster", "expander")
SCHEDULERS = ("static", "budget", "random", "round_robin")


def _lsq_problem(j, d=4, n=16, seed=0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(j, n, d)).astype(np.float32)
    w_true = rng.normal(size=d).astype(np.float32)
    b = A @ w_true + 0.01 * rng.normal(size=(j, n)).astype(np.float32)
    w_star = np.linalg.lstsq(A.reshape(-1, d), b.reshape(-1), rcond=None)[0]
    theta0 = {"w": jnp.asarray(rng.normal(size=(j, d)).astype(np.float32))}
    return (jnp.asarray(A), jnp.asarray(b)), theta0, w_star


def run(*, smoke: bool = False, j: int = 12, seeds: int = 3,
        max_iters: int = 400, post_epochs: int = 100) -> list[dict]:
    import jax.numpy as jnp
    from repro.core import ConsensusADMM, PenaltyConfig, build_graph
    from repro.topology import TopologyConfig

    from benchmarks.common import write_csv, write_json

    def _lsq_obj(data, th):
        Ai, bi = data
        return jnp.sum((Ai @ th["w"] - bi) ** 2)

    topologies = TOPOLOGIES[:2] if smoke else TOPOLOGIES
    schedulers = ("static", "budget") if smoke else SCHEDULERS
    if smoke:
        seeds, max_iters, post_epochs = 1, 150, 20

    rows = []
    for topo in topologies:
        g = build_graph(topo, j)
        adj_n = max(int(g.adj.sum()), 1)
        for sched in schedulers:
            tcfg = None if sched == "static" else TopologyConfig(
                scheduler=sched)
            iters, errs, mean_active, final_active = [], [], [], []
            for s in range(seeds):
                data, theta0, w_star = _lsq_problem(j, seed=3 + s)
                eng = ConsensusADMM(
                    objective=_lsq_obj,
                    penalty_cfg=PenaltyConfig(scheme="nap", eta0=1.0),
                    graph=g, inner_steps=30, inner_lr=1.0,
                    topology_cfg=tcfg)
                st = eng.init(theta0)
                st, hist = eng.run(st, data, max_iters=max_iters,
                                   rel_tol=1e-3)
                actives = []
                for _ in range(post_epochs):
                    st, m = eng.step(st, data)
                    if "active_edges" in m:
                        actives.append(float(m["active_edges"]))
                iters.append(hist["iterations"])
                errs.append(float(np.abs(
                    np.asarray(st.theta["w"]) - w_star).max()))
                if st.topo is not None:
                    mean_active.append(float(np.mean(actives)))
                    final_active.append(
                        float(np.asarray(st.topo.mask).sum() / adj_n))
                else:
                    mean_active.append(1.0)
                    final_active.append(1.0)
            rows.append({
                "nodes": j, "topology": topo, "scheduler": sched,
                "iters_median": float(np.median(iters)),
                "err_median": round(float(np.median(errs)), 5),
                "active_mean": round(float(np.median(mean_active)), 4),
                "active_final": round(float(np.median(final_active)), 4),
                "seeds": seeds,
            })
            print(f"topo_dyn J={j} {topo:9s} {sched:11s} "
                  f"iters={np.median(iters):5.0f} "
                  f"err={np.median(errs):.4f} "
                  f"active_final={np.median(final_active):.2f}", flush=True)
    write_csv("topology_dynamics.csv", rows)
    # results/ only — run.py promotes full-grid runs to the committed
    # repo-root baseline (benchmarks/common.py single-writer rule)
    write_json("BENCH_topology.json",
               {"j": j, "rel_tol": 1e-3, "smoke": smoke, "rows": rows})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid for CI")
    ap.add_argument("--seeds", type=int, default=3)
    args = ap.parse_args(argv)
    rows = run(smoke=args.smoke, seeds=args.seeds)
    # CI guard: the budget scheduler must not pay iterations for its wire
    # savings (acceptance: <= fixed-topology NAP) and must shed edges
    by = {(r["topology"], r["scheduler"]): r for r in rows}
    for topo in {r["topology"] for r in rows}:
        fixed, budget = by[(topo, "static")], by[(topo, "budget")]
        assert budget["iters_median"] <= fixed["iters_median"], (topo, by)
        if topo != "ring":              # ring is all-backbone: nothing to shed
            assert budget["active_final"] < 1.0, (topo, budget)
    print("topology_dynamics: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
