"""Paper Fig. 3 / Fig. 5: distributed affine SfM (turntable, 5 cameras).

Compares schemes on (a) ring vs complete topology and (b) t_max = 50 vs 5 —
the paper's demonstration that NAP keeps accelerating when the t_max-bound
methods degenerate to the baseline.
Metric: subspace angle of the consensus 3D structure vs centralized SVD,
and iterations to the relative-objective criterion.
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import write_csv


def run(seeds: int = 3, max_iters: int = 400) -> list[dict]:
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.core import PenaltyConfig, build_graph
    from repro.ppca import DPPCA, fit_svd, max_subspace_angle, turntable_sfm

    sfm = turntable_sfm(num_cameras=5, frames=30, points=90, seed=0)
    x = jnp.asarray(sfm.x_nodes)
    ref = fit_svd(jnp.asarray(sfm.measurements), 3)

    rows = []
    settings = [("ring", 50), ("complete", 50), ("complete", 5)]
    for topo, t_max in settings:
        g = build_graph(topo, 5)
        for scheme in ("fixed", "vp", "ap", "nap", "vp_ap", "vp_nap"):
            iters, angles = [], []
            for s in range(seeds):
                eng = DPPCA(latent_dim=3, graph=g,
                            penalty_cfg=PenaltyConfig(
                                scheme=scheme, eta0=10.0, t_max=t_max,
                                t_reset=t_max))
                st = eng.init(jax.random.PRNGKey(s), x)
                st, hist = eng.run(st, x, max_iters=max_iters,
                                   rel_tol=1e-3, min_iters=10)
                iters.append(hist["iterations"])
                angles.append(float(max_subspace_angle(st.W, ref.W)))
            rows.append({
                "topology": topo, "t_max": t_max, "scheme": scheme,
                "iters_median": float(np.median(iters)),
                "angle_median_deg": round(float(np.median(angles)), 3),
            })
            print(f"fig3 {topo:8s} tmax={t_max:2d} {scheme:7s} "
                  f"iters={np.median(iters):5.0f} "
                  f"angle={np.median(angles):6.2f}", flush=True)
    write_csv("fig3_sfm.csv", rows)
    return rows


if __name__ == "__main__":
    run()
