"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads dryrun_results.json and renders, per (arch x shape x mesh):
the three roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs
(useful-compute fraction), and the roofline fraction the cell achieves
(compute term / total of all three ~ how compute-bound the artifact is).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import write_csv

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "dryrun_results.json")


def run(path: str = DEFAULT_PATH) -> list[dict]:
    if not os.path.exists(path):
        print(f"roofline: no dry-run artifact at {path}; run "
              f"`python -m repro.launch.dryrun` first")
        return []
    with open(path) as f:
        recs = json.load(f)
    rows = []
    for r in recs:
        if r.get("skipped"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "SKIP",
                         "dominant": "-", "compute_s": "-", "memory_s": "-",
                         "collective_s": "-", "useful_frac": "-",
                         "roofline_frac": "-", "bytes_per_dev_gb": "-"})
            continue
        if "error" in r:
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "status": "ERROR",
                         "dominant": r["error"][:40], "compute_s": "-",
                         "memory_s": "-", "collective_s": "-",
                         "useful_frac": "-", "roofline_frac": "-",
                         "bytes_per_dev_gb": "-"})
            continue
        rl = r["roofline"]
        main = r.get("train") or r.get("prefill") or r.get("decode")
        total = rl["compute_s"] + rl["memory_s"] + rl["collective_s"]
        # fraction of step time that is irreducible compute at peak — the
        # closer to 1, the closer the artifact is to the compute roofline
        frac = rl["compute_s"] / total if total else 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "OK", "dominant": rl["dominant"],
            "compute_s": round(rl["compute_s"], 4),
            "memory_s": round(rl["memory_s"], 4),
            "collective_s": round(rl["collective_s"], 4),
            "useful_frac": round(r.get("useful_flop_frac", 0.0), 3),
            "roofline_frac": round(frac, 4),
            "bytes_per_dev_gb": round(main["bytes_per_device_gb"], 2),
        })
    write_csv("roofline.csv", rows)
    hdr = ("arch", "shape", "mesh", "status", "dominant", "compute_s",
           "memory_s", "collective_s", "useful_frac", "roofline_frac",
           "bytes_per_dev_gb")
    widths = [24, 12, 8, 6, 11, 10, 10, 13, 11, 13, 16]
    print("  ".join(h.ljust(w) for h, w in zip(hdr, widths)))
    for row in rows:
        print("  ".join(str(row[h]).ljust(w) for h, w in zip(hdr, widths)))
    return rows


if __name__ == "__main__":
    run()
