"""Benchmark driver: one harness per paper table/figure + system benches.

Prints a ``name,value,derived`` CSV summary at the end. Full sweeps:
``python -m benchmarks.run --full``.

Output layout (single-writer rule, see ``benchmarks/common.py``): every
benchmark module writes only under ``benchmarks/results/``; THIS driver is
the sole writer of the committed repo-root ``BENCH_*.json`` baselines — it
promotes a cell's results artifact after the cell succeeds on the full
grid (``--full``), so smoke/CI runs can never clobber a baseline.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (20 seeds etc.)")
    ap.add_argument("--only", default="all",
                    choices=["all", "fig2", "fig3", "hopkins", "roofline",
                             "consensus", "lm_ablation", "topology",
                             "async", "obs"])
    args = ap.parse_args(argv)
    seeds = 20 if args.full else 3

    summary = []

    def record(name, value, derived=""):
        summary.append((name, value, derived))

    def promote(name):
        # single-writer rule: only this driver touches root baselines,
        # and only when the full grid ran
        if args.full:
            from benchmarks.common import promote_baseline
            path = promote_baseline(name)
            if path:
                record(f"promoted_{name}", path)

    if args.only in ("all", "fig2"):
        from benchmarks import fig2_synthetic
        t0 = time.time()
        rows = fig2_synthetic.run(seeds=seeds if args.full else 2,
                                  sizes=(12, 16, 20) if args.full
                                  else (12, 20))
        by = {(r["nodes"], r["topology"], r["scheme"]): r for r in rows}
        for j in sorted({r["nodes"] for r in rows}):
            base = by.get((j, "complete", "fixed"))
            vp = by.get((j, "complete", "vp"))
            if base and vp:
                sp = 100 * (base["iters_median"] - vp["iters_median"]) \
                    / base["iters_median"]
                record(f"fig2_J{j}_complete_vp_speedup_pct", round(sp, 1),
                       f"baseline={base['iters_median']:.0f}it")
        record("fig2_wall_s", round(time.time() - t0, 1))

    if args.only in ("all", "fig3"):
        from benchmarks import fig3_sfm
        t0 = time.time()
        rows = fig3_sfm.run(seeds=seeds if args.full else 2)
        by = {(r["topology"], r["t_max"], r["scheme"]): r for r in rows}
        b5 = by.get(("complete", 5, "fixed"))
        n5 = by.get(("complete", 5, "nap"))
        if b5 and n5:
            sp = 100 * (b5["iters_median"] - n5["iters_median"]) \
                / b5["iters_median"]
            record("fig3_tmax5_nap_speedup_pct", round(sp, 1),
                   "NAP accelerates where t_max-bound methods cannot")
        record("fig3_wall_s", round(time.time() - t0, 1))

    if args.only in ("all", "hopkins"):
        from benchmarks import tab_hopkins
        t0 = time.time()
        rows = tab_hopkins.run(num_objects=20 if args.full else 6,
                               seeds=3 if args.full else 2)
        for r in rows:
            if r["topology"] == "complete" and r["scheme"] in ("vp", "vp_ap"):
                record(f"hopkins_complete_{r['scheme']}_speedup_pct",
                       r["speedup_vs_fixed_pct"],
                       "paper: vp=40.2 vp_ap=37.3")
        record("hopkins_wall_s", round(time.time() - t0, 1))

    if args.only in ("all", "roofline"):
        from benchmarks import roofline
        rows = roofline.run()
        ok = [r for r in rows if r["status"] == "OK"]
        if ok:
            fracs = [r["roofline_frac"] for r in ok]
            record("roofline_cells_ok", len(ok), f"of {len(rows)}")
            record("roofline_frac_median",
                   round(sorted(fracs)[len(fracs) // 2], 4))

    if args.only in ("all", "consensus"):
        # own subprocess: the ppca benches enable x64 globally, which the
        # trainer jit must not inherit (and a crash must not eat the summary)
        import os
        import subprocess
        env = dict(os.environ)
        env.setdefault("XLA_FLAGS",
                       "--xla_force_host_platform_device_count=8")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.consensus_overhead"],
            capture_output=True, text=True, env=env, timeout=1800)
        print(proc.stdout, end="")
        if proc.returncode == 0:
            import csv
            path = os.path.join(os.path.dirname(__file__), "results",
                                "consensus_overhead.csv")
            if os.path.exists(path):
                with open(path) as f:
                    for r in csv.DictReader(f):
                        if r["mode"] == "consensus_H16":
                            record("consensus_H16_wire_vs_allreduce",
                                   r["vs_allreduce"],
                                   "cross-pod bytes ratio")
            promote("BENCH_consensus.json")
        else:
            record("consensus_bench", "FAILED",
                   proc.stderr.strip().splitlines()[-1][:80]
                   if proc.stderr.strip() else "no stderr")

    if args.only in ("all", "topology"):
        from benchmarks import topology_dynamics
        t0 = time.time()
        rows = topology_dynamics.run(smoke=not args.full,
                                     seeds=seeds if args.full else 1)
        by = {(r["topology"], r["scheduler"]): r for r in rows}
        for topo in sorted({r["topology"] for r in rows}):
            b = by.get((topo, "budget"))
            if b:
                record(f"topology_{topo}_budget_active_final",
                       b["active_final"],
                       f"iters={b['iters_median']:.0f} (vs static "
                       f"{by[(topo, 'static')]['iters_median']:.0f})")
        record("topology_wall_s", round(time.time() - t0, 1))
        promote("BENCH_topology.json")

    if args.only in ("all", "async"):
        # own subprocess: needs the 8-device env like the consensus cell
        import os
        import subprocess
        env = dict(os.environ)
        env.setdefault("XLA_FLAGS",
                       "--xla_force_host_platform_device_count=8")
        cmd = [sys.executable, "-m", "benchmarks.async_staleness"]
        if not args.full:
            cmd.append("--smoke")
        proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                              timeout=1800)
        print(proc.stdout, end="")
        if proc.returncode == 0:
            import json
            path = os.path.join(os.path.dirname(__file__), "results",
                                "BENCH_async.json")
            if os.path.exists(path):
                with open(path) as f:
                    bench = json.load(f)
                for r in bench["rows"]:
                    record(f"async_speedup_wire{r['wire_frac']}",
                           r["speedup"],
                           f"sync={r['rounds_sync']}r "
                           f"async={r['ticks_async']}t")
                record("async_objective_drift", bench["objective_drift"],
                       "|f_async - f_sync| / f_sync")
            promote("BENCH_async.json")
        else:
            record("async_bench", "FAILED",
                   proc.stderr.strip().splitlines()[-1][:80]
                   if proc.stderr.strip() else "no stderr")

    if args.only in ("all", "obs"):
        # own subprocess: needs the 8-device env like the consensus cell
        import os
        import subprocess
        env = dict(os.environ)
        env.setdefault("XLA_FLAGS",
                       "--xla_force_host_platform_device_count=8")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.obs_overhead"],
            capture_output=True, text=True, env=env, timeout=1800)
        print(proc.stdout, end="")
        if proc.returncode == 0:
            import json
            path = os.path.join(os.path.dirname(__file__), "results",
                                "BENCH_obs.json")
            if os.path.exists(path):
                with open(path) as f:
                    bench = json.load(f)
                record("obs_overhead_pct",
                       round(100 * bench["obs_overhead_ratio"], 2),
                       f"on={bench['rounds']['obs_on']['round_ms']}ms "
                       f"off={bench['rounds']['obs_off']['round_ms']}ms")
            promote("BENCH_obs.json")
        else:
            record("obs_bench", "FAILED",
                   proc.stderr.strip().splitlines()[-1][:80]
                   if proc.stderr.strip() else "no stderr")

    if args.only in ("all", "lm_ablation"):
        import os
        import subprocess
        env = dict(os.environ)
        env.setdefault("XLA_FLAGS",
                       "--xla_force_host_platform_device_count=8")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.lm_scheme_ablation"],
            capture_output=True, text=True, env=env, timeout=1800)
        print(proc.stdout, end="")
        if proc.returncode == 0:
            import csv
            path = os.path.join(os.path.dirname(__file__), "results",
                                "lm_scheme_ablation.csv")
            if os.path.exists(path):
                with open(path) as f:
                    rows = list(csv.DictReader(f))
                best = min(rows, key=lambda r: float(r["final_loss"]))
                record("lm_ablation_best_scheme", best["scheme"],
                       f"loss={best['final_loss']}")

    print("\nname,value,derived")
    for name, value, derived in summary:
        print(f"{name},{value},{derived}")


if __name__ == "__main__":
    main()
