"""Paper Fig. 2: D-PPCA convergence, schemes x graph size x topology.

Synthetic subspace data (§5.1: 500 samples, D=20, M=5, noise 0.2I), median
over independent random initializations of (a) iterations to the paper's
relative-objective convergence criterion and (b) max subspace angle error.
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import write_csv


def run(seeds: int = 3, sizes=(12, 16, 20),
        topologies=("complete", "ring", "cluster"),
        schemes=("fixed", "vp", "ap", "nap", "vp_ap", "vp_nap"),
        max_iters: int = 400, eta0: float = 10.0) -> list[dict]:
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.core import PenaltyConfig, build_graph
    from repro.ppca import DPPCA, max_subspace_angle, subspace_data

    rows = []
    for j in sizes:
        data = subspace_data(j, seed=0)
        x = jnp.asarray(data.x)
        w_true = jnp.asarray(data.W_true)
        for topo in topologies:
            g = build_graph(topo, j)
            for scheme in schemes:
                iters, angles = [], []
                for s in range(seeds):
                    eng = DPPCA(latent_dim=5, graph=g,
                                penalty_cfg=PenaltyConfig(scheme=scheme,
                                                          eta0=eta0))
                    st = eng.init(jax.random.PRNGKey(100 + s), x)
                    st, hist = eng.run(st, x, max_iters=max_iters,
                                       rel_tol=1e-3, min_iters=10)
                    iters.append(hist["iterations"])
                    angles.append(float(max_subspace_angle(st.W, w_true)))
                rows.append({
                    "nodes": j, "topology": topo, "scheme": scheme,
                    "iters_median": float(np.median(iters)),
                    "angle_median_deg": round(float(np.median(angles)), 3),
                    "seeds": seeds,
                })
                print(f"fig2 J={j} {topo:8s} {scheme:7s} "
                      f"iters={np.median(iters):5.0f} "
                      f"angle={np.median(angles):6.2f}", flush=True)
    write_csv("fig2_synthetic.csv", rows)
    return rows


if __name__ == "__main__":
    run()
