"""Substrate tests: optimizer, data, checkpoint, fault tolerance, compression."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import latest_steps, restore, save, save_async, \
    wait_pending
from repro.core.graph import build_graph
from repro.core.penalty import PenaltyConfig, init_penalty_state
from repro.data import DataConfig, Prefetcher, SyntheticTokens
from repro.optim import adamw as al
from repro.optim import compression as cl
from repro.runtime import (ElasticController, RetryPolicy, StragglerMonitor,
                           shrink_penalty_state, with_retries)


# ---------------------------------------------------------------- adamw -----
def _quad_problem():
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3), "m": jnp.ones((4, 5))}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["m"] ** 2)

    return params, loss, target


@pytest.mark.parametrize("factored", [False, True])
def test_adamw_minimizes(factored):
    cfg = al.AdamWConfig(lr=0.05, weight_decay=0.0, factored=factored)
    params, loss, target = _quad_problem()
    state = al.init(cfg, params)
    for _ in range(300):
        grads = jax.grad(loss)(params)
        params, state, _ = al.update(cfg, state, params, grads)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)
    assert float(jnp.abs(params["m"]).max()) < 0.05


def test_adamw_factored_memory_shapes():
    cfg = al.AdamWConfig(factored=True)
    params = {"mat": jnp.zeros((64, 32)), "vec": jnp.zeros(16)}
    st = al.init(cfg, params)
    vr, vc = st.v["mat"]
    assert vr.shape == (64,) and vc.shape == (32,)
    assert st.v["vec"].shape == (16,)


def test_grad_clip_bounds_update():
    cfg = al.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    st = al.init(cfg, params)
    huge = {"w": jnp.full(4, 1e6)}
    p2, st, m = al.update(cfg, st, params, huge)
    assert float(m["grad_norm"]) > 1e5
    assert float(jnp.abs(p2["w"]).max()) < 20.0   # clip kept it sane


# ----------------------------------------------------------------- data -----
def test_data_deterministic_and_distinct():
    cfg = DataConfig(vocab=128, seq_len=16, batch_per_node=4, num_nodes=3,
                     seed=7)
    src = SyntheticTokens(cfg)
    b1 = src.batch(5)
    b2 = src.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = src.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # nodes see different data
    t = np.asarray(b1["tokens"])
    assert not np.array_equal(t[0], t[1])
    # probe stream is held out
    p = src.batch(5, probe=True)
    assert not np.array_equal(np.asarray(p["tokens"]), np.asarray(b1["tokens"]))
    # labels are next-token with masked tail
    lbl = np.asarray(b1["labels"])
    np.testing.assert_array_equal(lbl[:, :, :-1], t[:, :, 1:])
    assert np.all(lbl[:, :, -1] == -1)


def test_prefetcher_yields_in_order():
    cfg = DataConfig(vocab=64, seq_len=8, batch_per_node=2, num_nodes=1)
    pf = Prefetcher(SyntheticTokens(cfg), start_step=3, depth=2)
    it = iter(pf)
    steps = [next(it)[0] for _ in range(4)]
    pf.close()
    assert steps == [3, 4, 5, 6]


# ----------------------------------------------------------- checkpoint -----
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    save(str(tmp_path), 10, tree, metadata={"step": 10, "note": "x"})
    restored, meta = restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert meta["step"] == 10 and meta["note"] == "x"


def test_checkpoint_keep_k_and_latest(tmp_path):
    tree = {"w": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        save(str(tmp_path), s, tree, keep=2)
    assert latest_steps(str(tmp_path)) == [3, 4]


def test_checkpoint_rejects_wrong_structure(tmp_path):
    save(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore(str(tmp_path), {"a": jnp.zeros(3), "b": jnp.zeros(2)})
    with pytest.raises(ValueError):
        restore(str(tmp_path), {"a": jnp.zeros(4)})


def test_checkpoint_async(tmp_path):
    tree = {"w": jnp.full((8,), 3.0)}
    save_async(str(tmp_path), 5, tree, metadata={"step": 5})
    wait_pending()
    restored, meta = restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    """A crash mid-write (tmp dir left behind) must not corrupt restore."""
    tree = {"w": jnp.zeros(3)}
    save(str(tmp_path), 1, tree)
    os.makedirs(str(tmp_path / "tmp.2"))          # simulated dead write
    (tmp_path / "tmp.2" / "junk").write_text("partial")
    assert latest_steps(str(tmp_path)) == [1]
    restore(str(tmp_path), tree)


# ------------------------------------------------------- fault tolerance ----
def test_with_retries_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    out = with_retries(flaky, RetryPolicy(max_retries=3, backoff_s=0.0),
                       sleep=lambda _: None)()
    assert out == "ok" and calls["n"] == 3


def test_with_retries_exhausts():
    def always_bad():
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        with_retries(always_bad, RetryPolicy(max_retries=2, backoff_s=0.0),
                     sleep=lambda _: None)()


def test_straggler_monitor_flags_slow_node():
    mon = StragglerMonitor(4, threshold=2.0, patience=2)
    base = np.array([1.0, 1.0, 1.0, 1.0])
    assert mon.observe(base) == []
    slow = np.array([1.0, 1.0, 5.0, 1.0])
    assert mon.observe(slow) == []          # first strike
    assert mon.observe(slow) == [2]         # patience reached


def test_elastic_drop_preserves_adaptation_history():
    g = build_graph("ring", 5)
    pen = init_penalty_state(PenaltyConfig(scheme="nap"), 5)
    pen = pen._replace(eta=pen.eta.at[0, 1].set(42.0))
    ctl = ElasticController(g)
    g2, pen2 = ctl.drop(3, pen, step=100)
    assert g2.num_nodes == 4 and g2.is_connected()
    assert pen2.eta.shape == (4, 4)
    assert float(pen2.eta[0, 1]) == 42.0    # surviving edge kept its eta
    assert ctl.events[0].victim == 3


# ------------------------------------------------------------ compression ---
def test_int8_roundtrip_error_small():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=256).astype(np.float32))
    q, s = cl.compress_int8(x)
    back = cl.decompress_int8(q, s)
    assert float(jnp.abs(back - x).max()) <= float(s) * 0.51


def test_error_feedback_accumulates():
    cfg = cl.CompressionConfig(kind="topk", topk_frac=0.25)
    delta = {"w": jnp.asarray([10.0, 0.1, 0.2, 0.05])}
    err = cl.init_error(delta)
    sent, err, stats = cl.encode(cfg, delta, err)
    # only the top element got through; the rest is carried
    assert float(sent["w"][0]) == 10.0
    assert float(jnp.abs(err["w"][1:]).sum()) > 0
    # carried error is re-applied next round
    delta2 = {"w": jnp.zeros(4)}
    sent2, err2, _ = cl.encode(cfg, delta2, err)
    assert float(jnp.abs(sent2["w"]).sum()) > 0


def test_compression_ratio_reported():
    cfg = cl.CompressionConfig(kind="int8")
    delta = {"w": jnp.ones((128,))}
    _, _, stats = cl.encode(cfg, delta, cl.init_error(delta))
    assert stats["compression_ratio"] > 3.0


def test_checkpoint_bf16_roundtrip(tmp_path):
    """Extended dtypes (bf16) survive the npz round-trip via uint views."""
    import jax.numpy as jnp2
    tree = {"w": jnp2.asarray([1.5, -2.25, 0.007], jnp2.bfloat16),
            "m": jnp2.ones((4,), jnp2.float32)}
    save(str(tmp_path), 2, tree, metadata={"step": 2})
    restored, _ = restore(str(tmp_path), tree)
    assert restored["w"].dtype == jnp2.bfloat16
    np.testing.assert_array_equal(
        np.asarray(restored["w"], np.float32), np.asarray(tree["w"],
                                                          np.float32))
