"""Mini property-based testing helper (offline stand-in for `hypothesis`).

Draws cases from seeded strategies and reports the failing seed/case. No
shrinking, but the failing draw is fully reproducible from the printed seed.
"""
from __future__ import annotations

import numpy as np


def sweep(fn, *, cases: int = 20, seed: int = 0):
    """Run ``fn(rng, case_index)`` for ``cases`` independent seeded draws."""
    for i in range(cases):
        rng = np.random.default_rng(seed * 10_000 + i)
        try:
            fn(rng, i)
        except AssertionError as e:  # pragma: no cover
            raise AssertionError(
                f"property failed at case {i} (seed {seed * 10_000 + i}): {e}"
            ) from e


def draw_shape(rng, *, min_dim=1, max_dim=64, ndims=2) -> tuple[int, ...]:
    return tuple(int(rng.integers(min_dim, max_dim + 1)) for _ in range(ndims))


def draw_topology(rng, j: int) -> str:
    return str(rng.choice(["complete", "ring", "cluster", "chain", "star"]))


def draw_codec(rng) -> str:
    """Draw a wire-codec name (repro.wire.WIRE_CODECS), quantized-heavy:
    the native codec is a passthrough, so most draws should exercise a
    scale-carrying format."""
    return str(rng.choice(["native", "int8", "int8",
                           "fp8_e4m3", "fp8_e4m3", "fp8_e5m2"]))


def draw_param_tree(rng, *, j: int | None = None, max_leaves: int = 6,
                    max_elems: int = 2000, allow_empty: bool = True):
    """Random FlatLayout-shaped pytree: odd leaf sizes, mixed bf16/f32
    dtypes, scalar leaves and (optionally) empty leaves.

    Returns ``(tree, j)`` — a list of ``[j, ...]`` float arrays. Sizes are
    drawn odd-heavy so block-alignment padding is always exercised.
    """
    import jax.numpy as jnp

    j = int(rng.integers(1, 5)) if j is None else j
    nleaves = int(rng.integers(1, max_leaves + 1))
    dtypes = [np.float32, np.dtype(jnp.bfloat16)]
    tree = []
    for _ in range(nleaves):
        kind = rng.random()
        if kind < 0.15:
            shape = ()                                 # scalar leaf
        elif allow_empty and kind < 0.25:
            shape = (0,)                               # empty leaf
        else:
            ndims = int(rng.integers(1, 3))
            dims = [int(rng.integers(1, max_elems ** (1 / ndims)) * 2 - 1)
                    for _ in range(ndims)]             # odd-heavy sizes
            shape = tuple(max(1, d) for d in dims)
        dt = dtypes[int(rng.integers(0, len(dtypes)))]
        x = rng.normal(size=(j,) + shape).astype(np.float32)
        tree.append(jnp.asarray(x).astype(dt))
    return tree, j
