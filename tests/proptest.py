"""Mini property-based testing helper (offline stand-in for `hypothesis`).

Draws cases from seeded strategies and reports the failing seed/case. No
shrinking, but the failing draw is fully reproducible from the printed seed.
"""
from __future__ import annotations

import numpy as np


def sweep(fn, *, cases: int = 20, seed: int = 0):
    """Run ``fn(rng, case_index)`` for ``cases`` independent seeded draws."""
    for i in range(cases):
        rng = np.random.default_rng(seed * 10_000 + i)
        try:
            fn(rng, i)
        except AssertionError as e:  # pragma: no cover
            raise AssertionError(
                f"property failed at case {i} (seed {seed * 10_000 + i}): {e}"
            ) from e


def draw_shape(rng, *, min_dim=1, max_dim=64, ndims=2) -> tuple[int, ...]:
    return tuple(int(rng.integers(min_dim, max_dim + 1)) for _ in range(ndims))


def draw_topology(rng, j: int) -> str:
    return str(rng.choice(["complete", "ring", "cluster", "chain", "star"]))
