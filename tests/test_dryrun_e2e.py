"""Dry-run machinery end-to-end at debug scale (subprocess: 8 devices).

Exercises _compile_step/_corrected_record/lower-cell plumbing with reduced
configs on a small mesh — the same code paths the production 512-device
dry-run uses, cheap enough for CI.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses as dc
import jax
from repro.configs import get_reduced_config
from repro.configs.base import ShapeCell
from repro.launch import dryrun
from repro.launch.mesh import make_debug_mesh

out = {}
for arch, kind in [("qwen3-4b", "train"), ("rwkv6-7b", "train"),
                   ("moonshot-v1-16b-a3b", "train"),
                   ("qwen3-4b", "decode"), ("rwkv6-7b", "prefill")]:
    cfg = get_reduced_config(arch)
    cell = ShapeCell("tiny", 64, 8, kind)
    mesh = make_debug_mesh(multi_pod=(kind == "train"))
    rec = dryrun._corrected_record(cfg, cell, mesh,
                                   consensus=(kind == "train"))
    key = f"{arch[:8]}_{kind}"
    out[key] = {
        "flops": rec["flops_per_device"],
        "uncorrected": rec["uncorrected"]["flops_per_device"],
        "wire": rec["collectives"]["wire_total"],
    }
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def recs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_all_cells_lower_and_compile(recs):
    assert len(recs) == 5
    for k, v in recs.items():
        assert v["flops"] > 0, k


def test_trip_count_correction_increases_flops(recs):
    """Corrected FLOPs must exceed the while-body-once raw count."""
    for k, v in recs.items():
        assert v["flops"] >= v["uncorrected"] * 0.999, (k, v)
    # the 2-layer reduced configs still gain from the layer extrapolation
    assert recs["qwen3-4b_train"]["flops"] > \
        recs["qwen3-4b_train"]["uncorrected"]


def test_multi_pod_train_has_cross_pod_wire(recs):
    assert recs["qwen3-4b_train"]["wire"] > 0
