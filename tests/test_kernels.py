"""Per-kernel allclose tests: shape/dtype sweeps vs the pure-jnp oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as fa_raw
from repro.kernels.rwkv6_scan import rwkv6_scan as rw_raw

from proptest import sweep


# ------------------------------------------------------------ flash attn ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kh,s,hd,causal,window,bq,bk",
    [
        (1, 2, 2, 128, 32, True, 0, 64, 64),
        (2, 4, 2, 256, 64, True, 0, 128, 128),
        (1, 4, 1, 256, 32, True, 64, 64, 64),
        (1, 2, 2, 128, 32, False, 0, 32, 64),
        (1, 8, 2, 128, 128, True, 0, 128, 64),
    ])
def test_flash_attention_matches_ref(b, h, kh, s, hd, causal, window, bq, bk,
                                     dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, h, s, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, kh, s, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, kh, s, hd)), dtype)
    out = fa_raw(q, k, v, causal=causal, window=window, block_q=bq,
                 block_k=bk)
    n_rep = h // kh
    kr, vr = jnp.repeat(k, n_rep, 1), jnp.repeat(v, n_rep, 1)
    expect = ref.flash_attention_ref(q, kr, vr, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)


def test_flash_attention_property_sweep():
    def prop(rng, i):
        b = int(rng.integers(1, 3))
        kh = int(rng.choice([1, 2, 4]))
        h = kh * int(rng.choice([1, 2]))
        s = int(rng.choice([64, 128, 192]))
        hd = int(rng.choice([16, 32, 64]))
        q = jnp.asarray(rng.normal(size=(b, h, s, hd)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, kh, s, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, kh, s, hd)).astype(np.float32))
        out = fa_raw(q, k, v, causal=True, block_q=64, block_k=64)
        n_rep = h // kh
        expect = ref.flash_attention_ref(q, jnp.repeat(k, n_rep, 1),
                                         jnp.repeat(v, n_rep, 1), causal=True)
        assert float(jnp.max(jnp.abs(out - expect))) < 2e-5
    sweep(prop, cases=6, seed=11)


def test_flash_model_layout_wrapper_matches_model_ref():
    from repro.models.attention import flash_ref as model_ref
    rng = np.random.default_rng(3)
    b, s, h, hd = 2, 128, 4, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    expect = model_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


# ----------------------------------------------------------------- rwkv -----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,t,hd,chunk", [
    (1, 2, 64, 16, 16), (2, 3, 128, 32, 32), (1, 1, 96, 8, 32),
    (1, 4, 256, 64, 64),
])
def test_rwkv6_matches_ref(b, h, t, hd, chunk, dtype):
    rng = np.random.default_rng(1)
    r = jnp.asarray(rng.normal(size=(b, h, t, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, h, t, hd)) * 0.5, dtype)
    v = jnp.asarray(rng.normal(size=(b, h, t, hd)), dtype)
    lw = jnp.asarray(-np.exp(rng.normal(size=(b, h, t, hd)) * 0.5),
                     jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, hd)) * 0.1, jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(b, h, hd, hd)) * 0.1, jnp.float32)
    y, sf = rw_raw(r, k, v, lw, u, s0, chunk=chunk)
    yr, sr = ref.rwkv6_scan_ref(r, k, v, lw, u, s0)
    scale = float(np.abs(np.asarray(yr, np.float32)).max()) + 1e-6
    rtol = 3e-5 if dtype == jnp.float32 else 8e-3   # bf16: ~3 digits
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=rtol * scale)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr),
                               atol=max(rtol * scale, 1e-3))


def test_rwkv6_chunk_invariance():
    """Chunk size is a tiling knob — results must not depend on it."""
    rng = np.random.default_rng(5)
    b, h, t, hd = 1, 2, 128, 16
    r = jnp.asarray(rng.normal(size=(b, h, t, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, h, t, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, h, t, hd)).astype(np.float32))
    lw = jnp.asarray(-np.exp(rng.normal(size=(b, h, t, hd)) * 0.3)
                     .astype(np.float32))
    u = jnp.zeros((h, hd), jnp.float32)
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    y16, s16 = rw_raw(r, k, v, lw, u, s0, chunk=16)
    y64, s64 = rw_raw(r, k, v, lw, u, s0, chunk=64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s16), np.asarray(s64), atol=2e-4)


def test_rwkv6_model_integration_kernel_vs_scan():
    """time_mix(use_kernel=True) must equal the lax.scan reference path."""
    from repro.configs import get_reduced_config
    from repro.models import rwkv6 as rl
    from repro.models.params import materialize
    cfg = get_reduced_config("rwkv6-7b")
    p = materialize(jax.random.PRNGKey(0), rl.rwkv_defs(cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y_ref, s_ref_, _ = rl.time_mix(cfg, p, x, None, use_kernel=False)
    y_ker, s_ker, _ = rl.time_mix(cfg, p, x, None, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ker),
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_ref_), np.asarray(s_ker),
                               atol=2e-3)


# ------------------------------------------------------------- consensus ----
@pytest.mark.parametrize("n,bs", [(1024, 256), (4096, 4096), (65536, 16384)])
def test_consensus_update_matches_ref(n, bs):
    rng = np.random.default_rng(2)
    args = [jnp.asarray(rng.normal(size=n).astype(np.float32))
            for _ in range(5)]
    kw = dict(eta_sum=3.0, eta_node=2.0, step_size=0.01)
    t1, l1, r1, s1 = ops.consensus_update(*args, block_size=bs, **kw)
    t2, l2, r2, s2 = ref.consensus_update_ref(*args, **kw)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
    assert abs(float(r1 - r2)) / (float(r2) + 1e-9) < 1e-5
    assert abs(float(s1 - s2)) / (float(s2) + 1e-9) < 1e-5


def test_consensus_update_property_sweep():
    def prop(rng, i):
        n = int(rng.choice([256, 512, 2048]))
        args = [jnp.asarray(rng.normal(size=n).astype(np.float32))
                for _ in range(5)]
        kw = dict(eta_sum=float(rng.uniform(0.1, 20)),
                  eta_node=float(rng.uniform(0.1, 20)),
                  step_size=float(rng.uniform(1e-4, 0.1)))
        t1, l1, r1, s1 = ops.consensus_update(*args, block_size=n, **kw)
        t2, l2, r2, s2 = ref.consensus_update_ref(*args, **kw)
        assert float(jnp.max(jnp.abs(t1 - t2))) < 1e-4
        assert float(jnp.max(jnp.abs(l1 - l2))) < 1e-4
    sweep(prop, cases=8, seed=13)


@pytest.mark.parametrize("n,bs", [(1000, 256), (37, 64), (513, 128),
                                  (65537, 65536)])
def test_consensus_update_non_block_multiple(n, bs):
    """Regression: odd N must zero-pad internally, not assert (and the
    padded residual reductions must equal the unpadded oracle's)."""
    rng = np.random.default_rng(7)
    args = [jnp.asarray(rng.normal(size=n).astype(np.float32))
            for _ in range(5)]
    kw = dict(eta_sum=1.7, eta_node=0.9, step_size=0.05)
    t1, l1, r1, s1 = ops.consensus_update(*args, block_size=bs, **kw)
    t2, l2, r2, s2 = ref.consensus_update_ref(*args, **kw)
    assert t1.shape == (n,) and l1.shape == (n,)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
    assert abs(float(r1 - r2)) / (float(r2) + 1e-9) < 1e-5
    assert abs(float(s1 - s2)) / (float(s2) + 1e-9) < 1e-5


# ---------------------------------------------------- fused round kernel ----
def _round_case(rng, *, j, deg, nleaves, bs):
    sizes = [int(rng.integers(1, 4 * bs)) for _ in range(nleaves)]
    padded = [-(-s // bs) * bs for s in sizes]
    total = sum(padded)
    block_leaf, pieces = [], []
    for li, (s, p) in enumerate(zip(sizes, padded)):
        block_leaf += [li] * (p // bs)
        seg = np.zeros((j, p), np.float32)
        seg[:, :s] = rng.normal(size=(j, s))
        pieces.append(seg)
    theta = jnp.asarray(np.concatenate(pieces, axis=1))
    lam = jnp.asarray(rng.normal(size=(j, total)).astype(np.float32))
    barp = jnp.asarray(rng.normal(size=(j, total)).astype(np.float32))
    wires = jnp.asarray(
        rng.integers(-127, 128, size=(deg, j, total)).astype(np.int8))
    scales = jnp.asarray(
        rng.uniform(1e-3, 0.1, size=(deg, j, nleaves)).astype(np.float32))
    e_sym = jnp.asarray(
        rng.uniform(0.1, 3.0, size=(deg, j)).astype(np.float32))
    eta_sum = e_sym.sum(axis=0)
    alpha = 0.5 / (1.0 + 2.0 * eta_sum)
    eta_node = eta_sum / deg
    return (theta, lam, barp, wires, scales, e_sym, alpha, eta_sum,
            eta_node, tuple(block_leaf))


@pytest.mark.parametrize("whole_rows", [True, False])
@pytest.mark.parametrize("j,deg,nleaves,bs", [
    (2, 1, 3, 128), (4, 2, 5, 64), (3, 3, 1, 256),
])
def test_consensus_round_matches_ref(j, deg, nleaves, bs, whole_rows):
    """Both tilings — TPU-blocked grid and interpreter whole-row — vs ref."""
    rng = np.random.default_rng(11)
    (theta, lam, barp, wires, scales, e_sym, alpha, eta_sum, eta_node,
     block_leaf) = _round_case(rng, j=j, deg=deg, nleaves=nleaves, bs=bs)
    out_k = ops.consensus_round(theta, lam, barp, wires, scales, e_sym,
                                alpha, eta_sum, eta_node,
                                block_leaf=block_leaf, block_size=bs,
                                whole_rows=whole_rows)
    out_r = ref.consensus_round_ref(theta, lam, barp, wires, scales, e_sym,
                                    alpha, eta_sum, eta_node,
                                    block_leaf=block_leaf, block_size=bs)
    for a, b, name in zip(out_k, out_r,
                          ("theta", "lam", "bar", "r_sq", "s_sq")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5, err_msg=name)


def test_consensus_round_float_wire_property_sweep():
    """Uncompressed (f32 wire, unit scales) fused round == oracle."""
    def prop(rng, i):
        j = int(rng.integers(2, 5))
        deg = int(rng.integers(1, min(j, 3) + 1))
        bs = int(rng.choice([64, 128]))
        nleaves = int(rng.integers(1, 4))
        (theta, lam, barp, _, _, e_sym, alpha, eta_sum, eta_node,
         block_leaf) = _round_case(rng, j=j, deg=deg, nleaves=nleaves, bs=bs)
        total = theta.shape[1]
        wires = jnp.asarray(
            rng.normal(size=(deg, j, total)).astype(np.float32))
        scales = jnp.ones((deg, j, nleaves), jnp.float32)
        out_k = ops.consensus_round(theta, lam, barp, wires, scales, e_sym,
                                    alpha, eta_sum, eta_node,
                                    block_leaf=block_leaf, block_size=bs)
        out_r = ref.consensus_round_ref(theta, lam, barp, wires, scales,
                                        e_sym, alpha, eta_sum, eta_node,
                                        block_leaf=block_leaf, block_size=bs)
        for a, b in zip(out_k, out_r):
            scale = 1.0 + float(jnp.max(jnp.abs(b)))
            assert float(jnp.max(jnp.abs(a - b))) < 1e-4 * scale
    sweep(prop, cases=6, seed=23)
