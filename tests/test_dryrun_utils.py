"""Unit tests for the dry-run accounting (HLO parsing, roofline math)."""
import numpy as np
import pytest

from repro.launch.dryrun import (_shape_bytes, collective_bytes,
                                 roofline_terms)
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def test_shape_bytes():
    assert _shape_bytes("f32", "2,3") == 24
    assert _shape_bytes("bf16", "1024") == 2048
    assert _shape_bytes("pred", "8,8") == 64
    assert _shape_bytes("s32", "") == 4          # scalar
    assert _shape_bytes("token", "4") == 0       # unknown dtype ignored


def test_collective_bytes_parses_hlo():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %x), dimensions={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %rs = f32[8,32]{1,0} reduce-scatter(f32[64,32]{1,0} %z), dimensions={0}
  %cp = bf16[128]{0} collective-permute(bf16[128]{0} %w)
  %a2a = (f32[4,4]{1,0}) all-to-all(f32[4,4]{1,0} %v)
  %noise = f32[9]{0} add(f32[9]{0} %a, f32[9]{0} %b)
"""
    tot = collective_bytes(hlo)
    assert tot["all-gather"] == 16 * 1024 * 2
    assert tot["all-reduce"] == 256 * 4
    assert tot["reduce-scatter"] == 8 * 32 * 4
    assert tot["collective-permute"] == 128 * 2
    assert tot["all-to-all"] == 4 * 4 * 4
    # all-reduce double-counted on the wire
    expected = (16 * 1024 * 2 + 2 * 256 * 4 + 8 * 32 * 4 + 128 * 2
                + 4 * 4 * 4)
    assert tot["wire_total"] == expected


def test_collective_bytes_handles_start_ops():
    hlo = "%s = f32[64]{0} all-reduce-start(f32[64]{0} %x)"
    tot = collective_bytes(hlo)
    assert tot["all-reduce"] == 256


def test_roofline_terms_dominance():
    # pure-compute workload
    r = roofline_terms(PEAK_FLOPS_BF16, 0.0, 0.0, 256)
    assert r["dominant"] == "compute" and abs(r["compute_s"] - 1.0) < 1e-9
    # memory-bound workload
    r = roofline_terms(0.0, HBM_BW * 2, 0.0, 256)
    assert r["dominant"] == "memory" and abs(r["memory_s"] - 2.0) < 1e-9
    # collective-bound
    r = roofline_terms(1.0, 1.0, 50e9, 256)
    assert r["dominant"] == "collective"


def test_consensus_state_hbm_shrinks_by_inpod_size():
    """ISSUE acceptance (analytic half): per-device consensus-state HBM
    (lam + theta_bar_prev + wire/ledger rows) shrinks by ~the in-pod axis
    size on a 2-pod x 4-device mesh. The in-pod grid of that mesh is 4
    devices, so ``n_shards=4``; the only non-dividing term is the int8
    wire's 4*num_leaves scale tail, carried once per shard."""
    import jax.numpy as jnp
    from repro.launch.dryrun import consensus_state_bytes
    from repro.optim import flatten

    tree = {"w": jnp.zeros((4096, 64), jnp.float32),
            "b": jnp.zeros((1000,), jnp.float32),
            "e": jnp.zeros((3, 999), jnp.float32)}
    n_shards = 4                                  # 2-pod x 4-device mesh
    lay = flatten.FlatLayout.for_tree(tree, block_size=128,
                                      node_axis=False, shards=n_shards)
    for compression in ("none", "int8"):
        full = consensus_state_bytes(lay, deg=2, compression=compression,
                                     n_shards=1, with_ledger=True)
        slab = consensus_state_bytes(lay, deg=2, compression=compression,
                                     n_shards=n_shards, with_ledger=True)
        assert set(slab) == {"lam", "theta_bar_prev", "wire_rows",
                             "ledger_rows", "total"}
        # the flat f32 buffers divide exactly
        assert slab["lam"] * n_shards == full["lam"]
        assert slab["theta_bar_prev"] * n_shards == full["theta_bar_prev"]
        # the wire/ledger rows divide up to the per-shard scale tails
        ratio = full["total"] / slab["total"]
        assert 0.9 * n_shards <= ratio <= n_shards, (compression, ratio)
        if compression == "none":
            assert ratio == n_shards
        else:
            # exact overhead: (n_shards - 1) extra 4*L tails per offset
            # row, for wire and ledger rows
            extra = 2 * 2 * 4 * lay.num_leaves * (n_shards - 1)
            assert slab["total"] * n_shards == full["total"] + extra


def test_model_flops_yardstick():
    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import model_flops
    from repro.models import build_model
    m = build_model(get_config("qwen3-4b"))
    f = model_flops(m, SHAPES["train_4k"])
    # 6 * N * tokens within 20% of hand calc
    expect = 6.0 * m.active_param_count() * 256 * 4096
    assert abs(f - expect) / expect < 1e-6
    # decode counts one token per sequence
    f_dec = model_flops(m, SHAPES["decode_32k"])
    assert f_dec == 2.0 * m.active_param_count() * 128
