"""Unit + property tests for the core ADMM engine (graphs, penalties, ADMM)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (ConsensusADMM, PenaltyConfig, SCHEMES, build_graph,
                        compute_tau, consensus_error, drop_node,
                        init_penalty_state, local_residuals, neighbor_mean,
                        node_eta, update_penalty)

from proptest import sweep, draw_topology


# ------------------------------------------------------------------ graphs
@pytest.mark.parametrize("topo", ["complete", "ring", "cluster", "star",
                                  "chain", "expander"])
@pytest.mark.parametrize("j", [2, 5, 12, 20])
def test_graph_invariants(topo, j):
    g = build_graph(topo, j)
    assert g.num_nodes == j
    assert g.is_connected()
    assert np.array_equal(g.adj, g.adj.T)
    assert not np.any(np.diag(g.adj))


def test_complete_graph_properties():
    g = build_graph("complete", 12)
    assert g.num_edges == 12 * 11 // 2
    assert g.max_degree == 11
    # complete graph has the largest algebraic connectivity
    assert g.algebraic_connectivity() > build_graph("ring", 12).algebraic_connectivity()


def test_cluster_graph_is_papers_topology():
    g = build_graph("cluster", 12)
    # two complete 6-cliques plus one bridge
    assert g.num_edges == 2 * (6 * 5 // 2) + 1


def test_permutation_rounds_cover_all_edges_disjointly():
    def prop(rng, i):
        j = int(rng.integers(3, 16))
        g = build_graph(draw_topology(rng, j), j)
        rounds = g.permutation_rounds()
        seen = set()
        for rnd in rounds:
            srcs = [s for s, _ in rnd]
            dsts = [d for _, d in rnd]
            assert len(set(srcs)) == len(srcs), "duplicate src in a round"
            assert len(set(dsts)) == len(dsts), "duplicate dst in a round"
            seen |= set(rnd)
        assert seen == set(g.directed_edges())
    sweep(prop, cases=15, seed=1)


def test_drop_node_keeps_connectivity():
    def prop(rng, i):
        j = int(rng.integers(3, 14))
        g = build_graph(draw_topology(rng, j), j)
        victim = int(rng.integers(0, j))
        g2 = drop_node(g, victim)
        assert g2.num_nodes == j - 1
        assert g2.is_connected()
    sweep(prop, cases=20, seed=2)


# --------------------------------------------------------------- penalties
def _rand_probe(rng, j):
    f_self = jnp.asarray(rng.normal(size=j).astype(np.float32))
    f_nbr = jnp.asarray(rng.normal(size=(j, j)).astype(np.float32))
    return f_self, f_nbr


def test_tau_bounds_and_sign():
    """eq. (7): tau in [-1/2, 1]; better neighbor (lower f) => tau > 0."""
    def prop(rng, i):
        j = int(rng.integers(2, 12))
        g = build_graph(draw_topology(rng, j), j)
        adj = jnp.asarray(g.adj)
        f_self, f_nbr = _rand_probe(rng, j)
        tau = np.asarray(compute_tau(adj, f_self, f_nbr))
        assert np.all(tau >= -0.5 - 1e-5), tau.min()
        assert np.all(tau <= 1.0 + 1e-5), tau.max()
        assert np.all(tau[~np.asarray(g.adj)] == 0.0)
        # sign: f_i(theta_j) < f_i(theta_i)  =>  tau_ij >= 0
        fs = np.asarray(f_self)[:, None]
        fn = np.asarray(f_nbr)
        better = np.asarray(g.adj) & (fn < fs)
        assert np.all(tau[better] >= -1e-6)
    sweep(prop, cases=25, seed=3)


def test_ap_eta_ratio_bound():
    """§3.2: eta stays within [eta0/2, 2*eta0] for the AP scheme."""
    cfg = PenaltyConfig(scheme="ap", eta0=10.0)
    g = build_graph("complete", 8)
    adj = jnp.asarray(g.adj)
    st = init_penalty_state(cfg, 8)
    rng = np.random.default_rng(0)
    for _ in range(60):
        f_self, f_nbr = _rand_probe(rng, 8)
        st = update_penalty(cfg, st, adj=adj, f_self=f_self, f_nbr=f_nbr)
        eta = np.asarray(st.eta)[np.asarray(g.adj)]
        assert np.all(eta >= 5.0 - 1e-4) and np.all(eta <= 20.0 + 1e-4)
    # after t_max the penalty freezes at eta0
    assert np.allclose(np.asarray(st.eta)[np.asarray(g.adj)], 10.0)


def test_vp_reset_to_homogeneous():
    cfg = PenaltyConfig(scheme="vp", eta0=10.0, t_reset=5)
    g = build_graph("ring", 6)
    adj = jnp.asarray(g.adj)
    st = init_penalty_state(cfg, 6)
    rng = np.random.default_rng(1)
    for t in range(8):
        r = jnp.asarray(rng.uniform(0, 10, 6).astype(np.float32))
        s = jnp.asarray(rng.uniform(0, 0.1, 6).astype(np.float32))
        st = update_penalty(cfg, st, adj=adj, r_norm=r, s_norm=s)
    # t >= t_reset: homogeneous eta0 again (§3.1 reset rule)
    assert np.allclose(np.asarray(st.eta)[np.asarray(g.adj)], 10.0)


def test_nap_budget_is_bounded_geometric():
    """eq. (11): budget never exceeds T/(1-alpha)."""
    cfg = PenaltyConfig(scheme="nap", eta0=10.0, budget_init=1.0, alpha=0.5,
                        beta=1e-6, relative_beta=False)
    g = build_graph("complete", 6)
    adj = jnp.asarray(g.adj)
    st = init_penalty_state(cfg, 6)
    rng = np.random.default_rng(2)
    for _ in range(200):
        f_self, f_nbr = _rand_probe(rng, 6)
        st = update_penalty(cfg, st, adj=adj, f_self=f_self, f_nbr=f_nbr)
    bound = cfg.budget_init / (1.0 - cfg.alpha) + 1e-5
    assert np.all(np.asarray(st.budget) <= bound), np.asarray(st.budget).max()


def test_nap_budget_blocks_after_exhaustion():
    """Once the spent budget hits T_ij and f stops moving, eta freezes at eta0."""
    cfg = PenaltyConfig(scheme="nap", eta0=10.0, budget_init=0.3, alpha=0.5,
                        beta=0.5, relative_beta=False)
    g = build_graph("complete", 4)
    adj = jnp.asarray(g.adj)
    st = init_penalty_state(cfg, 4)
    rng = np.random.default_rng(3)
    f_self, f_nbr = _rand_probe(rng, 4)
    for _ in range(50):  # same objectives: f never moves => no top-up
        st = update_penalty(cfg, st, adj=adj, f_self=f_self, f_nbr=f_nbr)
    eta = np.asarray(st.eta)[np.asarray(g.adj)]
    cum = np.asarray(st.cum_tau)[np.asarray(g.adj)]
    assert np.all(cum >= 0.3) or np.allclose(eta, 10.0)
    assert np.allclose(eta, 10.0)  # exhausted edges are back at eta0


@pytest.mark.parametrize("scheme", SCHEMES)
def test_all_schemes_produce_finite_positive_eta(scheme):
    cfg = PenaltyConfig(scheme=scheme, eta0=10.0)
    g = build_graph("cluster", 8)
    adj = jnp.asarray(g.adj)
    st = init_penalty_state(cfg, 8)
    rng = np.random.default_rng(4)
    for _ in range(30):
        f_self, f_nbr = _rand_probe(rng, 8)
        r = jnp.asarray(rng.uniform(0, 5, 8).astype(np.float32))
        s = jnp.asarray(rng.uniform(0, 5, 8).astype(np.float32))
        st = update_penalty(cfg, st, adj=adj, f_self=f_self, f_nbr=f_nbr,
                            r_norm=r, s_norm=s)
        eta = np.asarray(st.eta)
        assert np.all(np.isfinite(eta)) and np.all(eta > 0)


# --------------------------------------------------------------- residuals
def test_neighbor_mean_complete_graph():
    j = 6
    g = build_graph("complete", j)
    theta = {"w": jnp.arange(j, dtype=jnp.float32)[:, None] * jnp.ones((j, 3))}
    bar = neighbor_mean(theta, jnp.asarray(g.adj))
    # for node i: mean of all others = (sum - i) / (j-1)
    total = np.arange(j).sum()
    expect = (total - np.arange(j)) / (j - 1)
    np.testing.assert_allclose(np.asarray(bar["w"])[:, 0], expect, rtol=1e-6)


def test_residuals_zero_at_consensus():
    j = 5
    g = build_graph("ring", j)
    theta = {"w": jnp.ones((j, 4))}
    bar_prev = neighbor_mean(theta, jnp.asarray(g.adj))
    rr = local_residuals(theta, bar_prev, jnp.asarray(g.adj), jnp.ones(j))
    np.testing.assert_allclose(np.asarray(rr.r_norm), 0.0, atol=1e-7)
    np.testing.assert_allclose(np.asarray(rr.s_norm), 0.0, atol=1e-7)


# ------------------------------------------------------- end-to-end ADMM
def _lsq_problem(j, d=4, n=16, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(j, n, d)).astype(np.float32)
    w_true = rng.normal(size=d).astype(np.float32)
    b = A @ w_true + 0.01 * rng.normal(size=(j, n)).astype(np.float32)
    w_star = np.linalg.lstsq(A.reshape(-1, d), b.reshape(-1), rcond=None)[0]
    theta0 = {"w": jnp.asarray(rng.normal(size=(j, d)).astype(np.float32))}
    return (jnp.asarray(A), jnp.asarray(b)), theta0, w_star


def _lsq_obj(data, th):
    Ai, bi = data
    return jnp.sum((Ai @ th["w"] - bi) ** 2)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_admm_converges_to_centralized_lsq(scheme):
    j = 6
    data, theta0, w_star = _lsq_problem(j)
    eng = ConsensusADMM(objective=_lsq_obj,
                        penalty_cfg=PenaltyConfig(scheme=scheme, eta0=1.0),
                        graph=build_graph("complete", j),
                        inner_steps=30, inner_lr=1.0)
    st = eng.init(theta0)
    st, hist = eng.run(st, data, max_iters=250, rel_tol=1e-8)
    w = np.asarray(st.theta["w"])
    assert np.abs(w - w_star).max() < 0.02, scheme
    assert float(consensus_error(st.theta)) < 0.02


def test_admm_topology_robustness():
    def prop(rng, i):
        j = int(rng.integers(3, 9))
        topo = draw_topology(rng, j)
        data, theta0, w_star = _lsq_problem(j, seed=i)
        eng = ConsensusADMM(objective=_lsq_obj,
                            penalty_cfg=PenaltyConfig(scheme="nap", eta0=1.0),
                            graph=build_graph(topo, j),
                            inner_steps=30, inner_lr=1.0)
        st = eng.init(theta0)
        st, _ = eng.run(st, data, max_iters=400, rel_tol=1e-9)
        w = np.asarray(st.theta["w"])
        assert np.abs(w - w_star).max() < 0.05, (topo, j)
    sweep(prop, cases=4, seed=7)


def test_expander_topology_scales_consensus():
    """Production-scale topology: expander mixes ~as fast as complete at a
    fraction of the edges (the J-in-the-hundreds pod-graph recommendation)."""
    j = 12
    data, theta0, w_star = _lsq_problem(j, seed=3)
    results = {}
    for topo in ("complete", "expander", "ring"):
        eng = ConsensusADMM(objective=_lsq_obj,
                            penalty_cfg=PenaltyConfig(scheme="nap", eta0=1.0),
                            graph=build_graph(topo, j),
                            inner_steps=30, inner_lr=1.0)
        st = eng.init(theta0)
        st, hist = eng.run(st, data, max_iters=250, rel_tol=1e-9)
        err = np.abs(np.asarray(st.theta["w"]) - w_star).max()
        results[topo] = (hist["iterations"], err)
    assert results["expander"][1] < 0.05
    # expander needs far fewer edges than complete but converges, unlike-
    # ring-slow: its iteration count stays within 3x of complete's
    assert results["expander"][0] <= results["complete"][0] * 3 + 20
    g_c = build_graph("complete", j)
    g_e = build_graph("expander", j)
    assert g_e.num_edges < g_c.num_edges / 2


def test_probe_midpoint_variant_converges():
    """§3.2 locality remark: probing at rho_ij=(theta_i+theta_j)/2."""
    j = 5
    data, theta0, w_star = _lsq_problem(j, seed=4)
    eng = ConsensusADMM(objective=_lsq_obj,
                        penalty_cfg=PenaltyConfig(scheme="ap", eta0=1.0),
                        graph=build_graph("complete", j),
                        inner_steps=30, inner_lr=1.0, probe_midpoint=True)
    st = eng.init(theta0)
    st, _ = eng.run(st, data, max_iters=250, rel_tol=1e-9)
    assert np.abs(np.asarray(st.theta["w"]) - w_star).max() < 0.05
