"""Unit tests for the CI benchmark-regression gate
(benchmarks/check_regression.py) — synthetic baseline/fresh pairs, no
devices needed. The gate's contract:

  * rows matched by key (tag / topology+scheduler / wire_frac); rows only
    on one side are reported, never failed (smoke grids run subsets);
  * per-metric tolerance kinds: ratio (timing), floor (speedups), abs
    (fractions), exact (byte accounting);
  * missing artifacts skip their file (the gate checks only what the
    preceding CI cells produced);
  * the report embeds BOTH documents — one diffable failure artifact.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__)))))

from benchmarks import check_regression as cr  # noqa: E402


def _write(dirpath, name, doc):
    os.makedirs(dirpath, exist_ok=True)
    with open(os.path.join(dirpath, name), "w") as f:
        json.dump(doc, f)


def _consensus(round_ms, wire_bytes, fused_vs_unfused=0.5):
    return {"rounds": {"fused_none": {"round_ms": round_ms,
                                      "wire_bytes_per_round": wire_bytes}},
            "fused_vs_unfused": fused_vs_unfused}


def _topology(iters, active=0.2):
    return {"rows": [{"topology": "ring", "scheduler": "budget",
                      "iters_median": iters, "active_final": active,
                      "err_median": 1e-4}]}


def _async(speedup, drift=0.004):
    return {"rows": [{"wire_frac": 0.5, "speedup": speedup,
                      "ticks_async": 6}],
            "objective_drift": drift}


def test_identical_results_pass(tmp_path):
    base, fresh = str(tmp_path / "base"), str(tmp_path / "fresh")
    _write(base, "BENCH_consensus.json", _consensus(50.0, 1000))
    _write(fresh, "BENCH_consensus.json", _consensus(50.0, 1000))
    _write(base, "BENCH_topology.json", _topology(70))
    _write(fresh, "BENCH_topology.json", _topology(70))
    _write(base, "BENCH_async.json", _async(2.0))
    _write(fresh, "BENCH_async.json", _async(2.0))
    rep = cr.run(base, fresh)
    assert rep["ok"] and rep["checks_run"] >= 6, rep


def test_timing_noise_within_ratio_passes(tmp_path):
    base, fresh = str(tmp_path / "b"), str(tmp_path / "f")
    _write(base, "BENCH_consensus.json", _consensus(50.0, 1000))
    _write(fresh, "BENCH_consensus.json", _consensus(150.0, 1000))  # 3x
    rep = cr.run(base, fresh, names=["BENCH_consensus.json"])
    assert rep["ok"], rep["failures"]


def test_timing_blowup_fails(tmp_path):
    base, fresh = str(tmp_path / "b"), str(tmp_path / "f")
    _write(base, "BENCH_consensus.json", _consensus(50.0, 1000))
    _write(fresh, "BENCH_consensus.json", _consensus(250.0, 1000))  # 5x
    rep = cr.run(base, fresh, names=["BENCH_consensus.json"])
    assert not rep["ok"]
    assert rep["failures"][0]["metric"] == "round_ms"


def test_unknown_tolerance_kind_raises(tmp_path):
    """A typo'd CHECKS entry must fail loudly, not silently pass."""
    with pytest.raises(ValueError):
        cr._check_metric("x", "ration", 2.5, 1.0, 1.0)


def test_wire_bytes_must_match_exactly(tmp_path):
    """Byte accounting is exact: wire bytes only change through a
    deliberate codec/layout change, which must update the baseline."""
    base, fresh = str(tmp_path / "b"), str(tmp_path / "f")
    _write(base, "BENCH_consensus.json", _consensus(50.0, 1000))
    _write(fresh, "BENCH_consensus.json", _consensus(50.0, 1001))
    rep = cr.run(base, fresh, names=["BENCH_consensus.json"])
    assert not rep["ok"]
    assert rep["failures"][0]["metric"] == "wire_bytes_per_round"


def test_speedup_floor(tmp_path):
    base, fresh = str(tmp_path / "b"), str(tmp_path / "f")
    _write(base, "BENCH_async.json", _async(2.0))
    _write(fresh, "BENCH_async.json", _async(1.6))      # >= 0.75x: OK
    assert cr.run(base, fresh, names=["BENCH_async.json"])["ok"]
    _write(fresh, "BENCH_async.json", _async(1.0))      # < 0.75x: fail
    rep = cr.run(base, fresh, names=["BENCH_async.json"])
    assert not rep["ok"]
    assert rep["failures"][0]["metric"] == "speedup"


def test_iteration_regression_fails(tmp_path):
    base, fresh = str(tmp_path / "b"), str(tmp_path / "f")
    _write(base, "BENCH_topology.json", _topology(70))
    _write(fresh, "BENCH_topology.json", _topology(120))
    rep = cr.run(base, fresh, names=["BENCH_topology.json"])
    assert not rep["ok"]
    assert rep["failures"][0]["metric"] == "iters_median"


def test_subset_and_superset_rows_never_fail(tmp_path):
    """Smoke grids run a subset of the baseline grid; extra fresh rows are
    reported as unmatched, missing ones simply aren't checked."""
    base, fresh = str(tmp_path / "b"), str(tmp_path / "f")
    doc = _topology(70)
    doc["rows"].append({"topology": "expander", "scheduler": "static",
                        "iters_median": 43, "active_final": 1.0,
                        "err_median": 0.0})
    _write(base, "BENCH_topology.json", doc)
    fresh_doc = _topology(70)
    fresh_doc["rows"].append({"topology": "cluster", "scheduler": "random",
                              "iters_median": 74, "active_final": 0.7,
                              "err_median": 5e-4})
    _write(fresh, "BENCH_topology.json", fresh_doc)
    rep = cr.run(base, fresh, names=["BENCH_topology.json"])
    assert rep["ok"], rep["failures"]
    assert rep["reports"][0]["unmatched_rows"] == ["('cluster', 'random')"]


def test_missing_fresh_artifact_skips(tmp_path):
    base, fresh = str(tmp_path / "b"), str(tmp_path / "f")
    _write(base, "BENCH_async.json", _async(2.0))
    os.makedirs(fresh, exist_ok=True)
    rep = cr.run(base, fresh, names=["BENCH_async.json"])
    assert rep["ok"] and rep["checks_run"] == 0
    assert "skipped" in rep["reports"][0]["status"]


def test_report_embeds_both_documents(tmp_path):
    """Failure diagnosis needs baseline AND fresh in ONE artifact."""
    base, fresh = str(tmp_path / "b"), str(tmp_path / "f")
    _write(base, "BENCH_consensus.json", _consensus(50.0, 1000))
    _write(fresh, "BENCH_consensus.json", _consensus(400.0, 999))
    rep = cr.run(base, fresh, names=["BENCH_consensus.json"])
    r = rep["reports"][0]
    assert r["status"] == "REGRESSION"
    assert r["baseline_doc"]["rounds"]["fused_none"]["round_ms"] == 50.0
    assert r["fresh_doc"]["rounds"]["fused_none"]["round_ms"] == 400.0


def test_main_exit_codes_and_report_file(tmp_path):
    base, fresh = str(tmp_path / "b"), str(tmp_path / "f")
    _write(base, "BENCH_consensus.json", _consensus(50.0, 1000))
    _write(fresh, "BENCH_consensus.json", _consensus(50.0, 1000))
    rc = cr.main(["--baseline-dir", base, "--results-dir", fresh])
    assert rc == 0
    assert os.path.exists(os.path.join(fresh, "regression_report.json"))
    _write(fresh, "BENCH_consensus.json", _consensus(50.0, 2000))
    assert cr.main(["--baseline-dir", base, "--results-dir", fresh]) == 1


def test_gate_covers_all_committed_baselines():
    """Every committed root baseline has a tolerance spec in the gate."""
    from benchmarks.common import REPO_ROOT
    committed = [n for n in os.listdir(REPO_ROOT)
                 if n.startswith("BENCH_") and n.endswith(".json")]
    assert set(committed) == set(cr.CHECKS), (committed, set(cr.CHECKS))
