"""Tests for the observability subsystem (repro.obs).

Three layers:
  * schema layer — the unified round-metrics registry is a STABILITY pin:
    ring column order is append-only, extra keys are rejected, zero is the
    defined not-applicable value for async-only metrics on the sync path;
  * host layer — ring wraparound/drain semantics (pure read, cursor,
    overflow accounting), topology event journal diffing on synthetic
    snapshots, exporter artifact well-formedness, RoundClock -> Perfetto
    reconstruction;
  * engine pins (subprocess, 8 fake devices) —
      - sync, async and sharded rounds emit the IDENTICAL metrics key set
        (the metrics-shape-drift satellite pin),
      - the ring appends under jit+donation with steps stamped, on the
        sharded engine too,
      - ``obs=None`` and ``ObsConfig(enabled=False)`` lower BYTE-IDENTICAL
        HLO (zero compiled-step footprint when off — the acceptance pin),
      - the ring exists in TrainState only when obs is enabled.
"""
import json
import os
import subprocess
import sys
from types import SimpleNamespace

import numpy as np
import pytest

from repro.obs import (ObsConfig, diff_events, drain, drain_rows, init_ring,
                       ring_append, snapshot)
from repro.obs import schema

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -------------------------------------------------------- schema layer ----
def test_schema_column_order_is_pinned():
    """Ring columns are a wire format: existing columns NEVER renumber.

    Appending a new metric is fine (add it to the end of ROUND_METRICS and
    extend this pin); reordering or renaming breaks every drained artifact
    on disk and requires a SCHEMA_VERSION bump instead.
    """
    assert schema.RING_COLUMNS == (
        "step", "r_max", "s_max", "f_mean", "eta_mean", "active_edges",
        "stale_edges", "age_max")
    assert schema.NUM_COLUMNS == 8
    assert schema.COLUMN_INDEX["step"] == 0
    assert schema.COLUMN_INDEX["age_max"] == 7
    assert schema.SCHEMA_VERSION == 1


def test_unify_pads_missing_and_rejects_unregistered():
    out = schema.unify_round_metrics({"r_max": 1.0, "s_max": 2.0})
    assert tuple(out) == schema.ROUND_METRICS       # registry order
    assert float(out["stale_edges"]) == 0.0
    assert out["age_max"].dtype == np.int32         # typed zero
    with pytest.raises(ValueError, match="unregistered"):
        schema.unify_round_metrics({"r_max": 1.0, "my_new_metric": 3.0})


def test_metrics_row_roundtrips_through_row_to_dict():
    row = schema.metrics_row(7, {"r_max": 0.5, "age_max": 3})
    assert row.shape == (schema.NUM_COLUMNS,)
    d = schema.row_to_dict(np.asarray(row))
    assert d["step"] == 7 and isinstance(d["step"], int)
    assert d["age_max"] == 3 and isinstance(d["age_max"], int)
    assert d["r_max"] == pytest.approx(0.5)
    assert d["s_max"] == 0.0


def test_obs_config_validation():
    with pytest.raises(ValueError):
        ObsConfig(ring_capacity=0)
    with pytest.raises(ValueError):
        ObsConfig(drain_every=0)
    assert ObsConfig().enabled is True


# ---------------------------------------------------------- ring layer ----
def _rows(n, start=0):
    return [schema.metrics_row(start + k, {"r_max": float(start + k)})
            for k in range(n)]


def test_ring_drain_is_chronological_and_pure():
    ring = init_ring(8)
    for row in _rows(3):
        ring = ring_append(ring, row)
    rows, cursor, dropped = drain(ring, 0)
    assert dropped == 0 and cursor == 3
    assert rows[:, schema.COLUMN_INDEX["step"]].tolist() == [0, 1, 2]
    # pure read: same cursor -> same rows, device state untouched
    rows2, _, _ = drain(ring, 0)
    assert np.array_equal(rows, rows2)
    assert int(ring.head) == 3
    # cursor honored: nothing new since
    rows3, cursor3, _ = drain(ring, cursor)
    assert rows3.shape[0] == 0 and cursor3 == 3


def test_ring_wraparound_reports_dropped_rows():
    ring = init_ring(4)
    for row in _rows(7):                 # 7 appends into cap 4
        ring = ring_append(ring, row)
    rows, cursor, dropped = drain(ring, 0)
    assert dropped == 3                  # rows 0,1,2 overwritten
    assert cursor == 7
    # survivors are the newest cap rows, still chronological
    assert rows[:, schema.COLUMN_INDEX["step"]].tolist() == [3, 4, 5, 6]


def test_ring_append_wraps_under_jit():
    import jax

    @jax.jit
    def appends(ring):
        for row in _rows(5):
            ring = ring_append(ring, row)
        return ring

    ring = appends(init_ring(4))
    assert int(ring.head) == 5
    rows, _, dropped = drain(ring, 0)
    assert dropped == 1
    assert rows[:, 0].tolist() == [1, 2, 3, 4]


def test_drain_rows_dict_form():
    ring = init_ring(4)
    ring = ring_append(ring, schema.metrics_row(9, {"age_max": 2}))
    rows, cursor, _ = drain_rows(ring, 0)
    assert cursor == 1
    assert rows[0]["step"] == 9 and rows[0]["age_max"] == 2
    assert set(rows[0]) == set(schema.RING_COLUMNS)


# ------------------------------------------------------- journal layer ----
def _topo(j=4, **kw):
    base = dict(mask=np.ones((j, j), bool), node_alive=np.ones(j, bool),
                repair=np.zeros((j, j), bool), age=np.zeros((j, j), np.int32),
                kick=np.zeros((j, j), np.float32))
    base.update(kw)
    return SimpleNamespace(**base)


def _pen(j=4, **kw):
    base = dict(eta=np.full((j, j), 0.1, np.float32),
                cum_tau=np.zeros((j, j), np.float32),
                budget=np.ones((j, j), np.float32),
                n_incr=np.zeros((j, j), np.int32))
    base.update(kw)
    return SimpleNamespace(**base)


def test_journal_diff_gate_revive_and_churn():
    prev = snapshot(_topo(), _pen())
    mask = np.ones((4, 4), bool)
    mask[0, 1] = mask[1, 0] = False      # symmetric gate
    mask[2, 3] = False                   # one-sided flip gates too: an edge
                                         # is active iff BOTH directions are
    alive = np.ones(4, bool)
    alive[3] = False
    repair = np.zeros((4, 4), bool)
    repair[1, 2] = True
    cur = snapshot(_topo(mask=mask, node_alive=alive, repair=repair), _pen())
    ev = diff_events(prev, cur, step=5)
    by = {}
    for e in ev:
        by.setdefault(e["event"], []).append(e)
    assert [e["edge"] for e in by["edge_gated"]] == [[0, 1], [2, 3]]
    assert by["edge_gated"][0]["step"] == 5
    assert by["node_dropped"][0]["node"] == 3
    assert by["repair_activated"][0]["edge"] == [1, 2]
    assert "edge_revived" not in by
    # revive is the reverse diff
    ev_back = diff_events(cur, prev, step=6)
    assert any(e["event"] == "edge_revived" and e["edge"] == [0, 1]
               for e in ev_back)


def test_journal_diff_staleness_and_kick():
    prev = snapshot(_topo(), _pen())
    age = np.zeros((4, 4), np.int32)
    age[1, 2] = 3                        # symmetrized: max(age, age.T)
    kick = np.zeros((4, 4), np.float32)
    kick[0, 3] = kick[3, 0] = 0.5
    cur = snapshot(_topo(age=age, kick=kick), _pen())
    ev = diff_events(prev, cur, step=2, max_staleness=1)
    kinds = {e["event"]: e for e in ev}
    assert kinds["stale_gated"]["edge"] == [1, 2]
    assert kinds["stale_gated"]["age"] == 3
    assert kinds["kick_parked"]["edge"] == [0, 3]
    assert kinds["kick_parked"]["weight"] == pytest.approx(0.5)
    ev_back = diff_events(cur, prev, step=3, max_staleness=1)
    kinds = {e["event"]: e for e in ev_back}
    assert kinds["stale_revived"]["edge"] == [1, 2]
    assert kinds["kick_absorbed"]["weight"] == pytest.approx(0.5)
    # without the bound there are no staleness events (executor config)
    assert not any("stale" in e["event"]
                   for e in diff_events(prev, cur, step=2))


def test_journal_diff_budget_lifecycle_is_directed():
    prev = snapshot(_topo(), _pen())
    tau = np.zeros((4, 4), np.float32)
    tau[0, 1] = 2.0                      # exhausted one direction only
    n_incr = np.zeros((4, 4), np.int32)
    n_incr[2, 0] = 1
    cur = snapshot(_topo(), _pen(cum_tau=tau, n_incr=n_incr,
                                 budget=np.full((4, 4), 1.5, np.float32)))
    ev = diff_events(prev, cur, step=9)
    kinds = {e["event"]: e for e in ev}
    assert kinds["budget_exhausted"]["edge"] == [0, 1]
    assert kinds["budget_exhausted"]["cum_tau"] == pytest.approx(2.0)
    assert kinds["budget_topup"]["edge"] == [2, 0]
    assert kinds["budget_topup"]["n_incr"] == 1
    assert sum(e["event"] == "budget_exhausted" for e in ev) == 1


def test_event_journal_baseline_and_jsonl(tmp_path):
    from repro.obs import EventJournal
    path = str(tmp_path / "events.jsonl")
    with EventJournal(path, max_staleness=1) as j:
        assert j.observe(_topo(), _pen(), step=0) == []   # baseline
        mask = np.ones((4, 4), bool)
        mask[0, 2] = mask[2, 0] = False
        ev = j.observe(_topo(mask=mask), _pen(), step=4)
        assert len(ev) == 1
        assert j.observe(_topo(mask=mask), _pen(), step=8) == []  # no diff
    lines = [json.loads(ln) for ln in open(path)]
    assert lines == [{"step": 4, "event": "edge_gated",
                      "edge": [0, 2], "eta": pytest.approx(0.1)}]


# -------------------------------------------------------- export layer ----
def test_obs_writer_artifact_set(tmp_path):
    from repro.obs import ObsWriter, validate_obs_dir
    d = str(tmp_path / "run")
    w = ObsWriter(d, meta={"wire_codec": "native",
                           "wire_bytes_per_round": 123, "offsets": [1]})
    w.append_metrics([schema.row_to_dict(np.asarray(r)) for r in _rows(3)])
    w.journal.observe(_topo(), _pen(), step=0)
    rollup = w.finalize(extra={"note": "test"})
    assert rollup["rounds"] == 3
    assert rollup["convergence"]["r_max"] == [0.0, 1.0, 2.0]
    assert rollup["wire"]["wire_bytes_per_round"] == 123
    assert rollup["note"] == "test"
    report = validate_obs_dir(d)
    assert report["ok"], report["errors"]
    assert report["files"]["metrics.jsonl"]["rows"] == 3
    # clock trace is optional, its absence is reported but not failed
    assert report["files"]["roundclock_trace.json"]["present"] is False


def test_validator_fails_on_missing_and_malformed(tmp_path):
    from repro.obs import validate_obs_dir
    d = str(tmp_path / "broken")
    os.makedirs(d)
    report = validate_obs_dir(d)
    assert not report["ok"]
    assert any("metrics.jsonl: missing" in e for e in report["errors"])
    # a metrics row missing schema keys is an error too
    for name in ("run.json", "rollup.json"):
        with open(os.path.join(d, name), "w") as f:
            json.dump({"rounds": 0, "convergence": {}, "staleness": {}}, f)
    with open(os.path.join(d, "events.jsonl"), "w"):
        pass
    with open(os.path.join(d, "metrics.jsonl"), "w") as f:
        f.write(json.dumps({"step": 1}) + "\n")
    report = validate_obs_dir(d)
    assert any("missing keys" in e for e in report["errors"])


def test_roundclock_perfetto_reconstruction(tmp_path):
    from repro.async_exec import RoundClock, straggler_compute
    from repro.obs import roundclock_trace_events, write_roundclock_trace
    clock = RoundClock(compute_s=straggler_compute(3, factor=2.0),
                       wire_s=0.25, offsets=(1,))
    for _ in range(4):
        clock.tick()
    ev = roundclock_trace_events(clock)
    spans = [e for e in ev if e["ph"] == "X" and e["cat"] == "compute"]
    wires = [e for e in ev if e["ph"] == "X" and e["cat"] == "wire"]
    ticks = [e for e in ev if e["ph"] == "i"]
    assert len(spans) == int(np.sum(clock.rounds_done))
    assert len(wires) == len(spans)      # every round sends once
    assert len(ticks) == 4
    # straggler node 0 rounds are 2x wide; sends start at round end
    w0 = [e for e in spans if e["tid"] == 0][0]
    w1 = [e for e in spans if e["tid"] == 1][0]
    assert w0["dur"] == pytest.approx(2 * w1["dur"])
    s1 = [e for e in wires if e["tid"] == 3 + 1][0]
    assert s1["ts"] == pytest.approx(w1["ts"] + w1["dur"])
    path = write_roundclock_trace(clock, str(tmp_path / "t.json"))
    doc = json.load(open(path))
    assert doc["traceEvents"] and doc["otherData"]["tick_s"] == clock.tick_s


# ----------------------------------------------- engine layer (8 dev) ----
_ENGINE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
from repro.async_exec import AsyncConfig, AsyncExecutor
from repro.configs import get_reduced_config
from repro.core.penalty import PenaltyConfig
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.obs import ObsConfig
from repro.obs import ring as ring_lib
from repro.obs import schema
from repro.optim import ConsensusConfig, ConsensusTrainer
from repro.optim.adamw import AdamWConfig
from repro.topology import TopologyConfig

out = {}
mesh = make_mesh((4, 2, 1), ("pod", "data", "model"))
cfg = get_reduced_config("qwen3-4b")
model = build_model(cfg)
data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  batch_per_node=2, num_nodes=4))
probe = data.batch(0, probe=True)

def make(obs=None, async_cfg=None, sharded=False):
    return ConsensusTrainer(
        model, mesh, adamw=AdamWConfig(lr=1e-2),
        consensus=ConsensusConfig(
            penalty=PenaltyConfig(scheme="nap", eta0=0.1),
            topology="ring", local_steps=1,
            dyn_topology=TopologyConfig(),
            async_exec=async_cfg, shard_consensus=sharded, obs=obs))

# --- 1. obs off leaves ZERO footprint: byte-identical HLO ---------------
hlo = {}
for tag, obs in (("none", None), ("disabled", ObsConfig(enabled=False)),
                 ("enabled", ObsConfig(ring_capacity=8))):
    tr = make(obs=obs)
    st = tr.init_state(jax.random.PRNGKey(0))
    hlo[tag] = jax.jit(tr.consensus_step).lower(st, probe).as_text()
    if tag != "enabled":
        out[f"ring_is_none_{tag}"] = st.ring is None
out["hlo_off_byte_identical"] = hlo["none"] == hlo["disabled"]
out["hlo_enabled_differs"] = hlo["none"] != hlo["enabled"]
out["hlo_enabled_has_ring_write"] = (
    "dynamic_update_slice" in hlo["enabled"]        # stablehlo spelling
    or "dynamic-update-slice" in hlo["enabled"])    # hlo spelling

# --- 2. ring under the REAL jitted step fns (donation path) -------------
results = {}
for tag, kw in (("sync", {}), ("sharded", {"sharded": True})):
    tr = make(obs=ObsConfig(ring_capacity=8), **kw)
    st = tr.init_state(jax.random.PRNGKey(0))
    train, cons = tr.jit_step_fns()
    for s in range(3):      # launcher cadence: train step then round, so
        st, m = train(st, data.batch(s))        # the stamped steps differ
        st, m = cons(st, data.batch(s, probe=True))
    rows, cursor, dropped = ring_lib.drain_rows(st.ring, 0)
    results[tag] = (rows, m)
    out[f"{tag}_ring_rows"] = len(rows)
    out[f"{tag}_ring_dropped"] = dropped
    out[f"{tag}_ring_steps"] = [r["step"] for r in rows]
    out[f"{tag}_keys"] = sorted(m)

# --- 3. async executor rounds append too, same key set ------------------
tra = make(obs=ObsConfig(ring_capacity=8),
           async_cfg=AsyncConfig(max_staleness=1))
sta = tra.init_state(jax.random.PRNGKey(0))
train_a = tra.jit_step_fns()[0]
sta, _ = train_a(sta, data.batch(0))
ex = AsyncExecutor(tra)
for s in range(1, 4):
    sta, ma = ex.consensus_round(sta, probe)
rows_a, _, _ = ring_lib.drain_rows(sta.ring, 0)
out["async_ring_rows"] = len(rows_a)
out["async_keys"] = sorted(ma)
out["schema_keys"] = sorted(schema.ROUND_METRICS)
out["row_keys_match_schema"] = all(
    set(r) == set(schema.RING_COLUMNS) for r in results["sync"][0] + rows_a)
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def engine_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _ENGINE], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_obs_off_is_byte_identical_hlo(engine_results):
    """Acceptance pin: with obs unset (or enabled=False) the compiled
    consensus step is BYTE-IDENTICAL to a build that never heard of obs —
    no ring in the state, no spans in the HLO metadata, nothing."""
    assert engine_results["hlo_off_byte_identical"] is True
    assert engine_results["ring_is_none_none"] is True
    assert engine_results["ring_is_none_disabled"] is True


def test_obs_enabled_adds_exactly_the_ring_write(engine_results):
    assert engine_results["hlo_enabled_differs"] is True
    assert engine_results["hlo_enabled_has_ring_write"] is True


def test_ring_appends_under_jit_and_donation(engine_results):
    """The jitted (donating) step fns append one stamped row per round on
    both the replicated and the sharded engine; the pure-read drain sees
    them all."""
    for tag in ("sync", "sharded"):
        assert engine_results[f"{tag}_ring_rows"] == 3
        assert engine_results[f"{tag}_ring_dropped"] == 0
        steps = engine_results[f"{tag}_ring_steps"]
        assert steps == sorted(steps) and len(set(steps)) == 3
    assert engine_results["async_ring_rows"] == 3


def test_metrics_key_set_is_unified(engine_results):
    """The metrics-shape-drift satellite pin: sync, sharded and async
    rounds all emit exactly the registered ROUND_METRICS key set."""
    want = engine_results["schema_keys"]
    assert engine_results["sync_keys"] == want
    assert engine_results["sharded_keys"] == want
    assert engine_results["async_keys"] == want
    assert engine_results["row_keys_match_schema"] is True
