"""Tests for the observability subsystem (repro.obs).

Four layers:
  * schema layer — the unified round- and node-metrics registries are
    STABILITY pins: column order is append-only, extra keys are rejected,
    zero is the defined not-applicable value for async-only metrics on
    the sync path, and step cells are int32-bitcast (exact above 2^24 —
    the SCHEMA_VERSION 2 regression pin);
  * host layer — scalar- and node-ring wraparound/drain semantics (pure
    read, cursor, cumulative overflow accounting across multiple wraps),
    topology event journal diffing on synthetic snapshots, the health
    detector bank on synthetic traces (each detector fires exactly where
    the trace was constructed to trip it), exporter artifact
    well-formedness + drain wall-clock timing, RoundClock -> Perfetto
    reconstruction;
  * dashboard layer — render an obs dir to one self-contained HTML and
    self-check every manifest-promised series is present;
  * engine pins (subprocess, 8 fake devices) —
      - sync, async and sharded rounds emit the IDENTICAL metrics key set
        (the metrics-shape-drift satellite pin),
      - both rings append under jit+donation with steps stamped, on the
        sharded and async engines too, and the sharded engine's node
        residuals match the replicated engine's (post-psum values),
      - ``obs=None`` and ``ObsConfig(enabled=False)`` lower BYTE-IDENTICAL
        HLO (zero compiled-step footprint when off — the acceptance pin),
        and ``with_node_ring=False`` compiles the node ring out,
      - the rings exist in TrainState only when their gate is on.
"""
import json
import os
import subprocess
import sys
from types import SimpleNamespace

import numpy as np
import pytest

from repro.obs import (ObsConfig, diff_events, drain, drain_rows, init_ring,
                       ring_append, snapshot)
from repro.obs import schema

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -------------------------------------------------------- schema layer ----
def test_schema_column_order_is_pinned():
    """Ring columns are a wire format: existing columns NEVER renumber.

    Appending a new metric is fine (add it to the end of ROUND_METRICS and
    extend this pin); reordering or renaming breaks every drained artifact
    on disk and requires a SCHEMA_VERSION bump instead.
    """
    assert schema.RING_COLUMNS == (
        "step", "r_max", "s_max", "f_mean", "eta_mean", "active_edges",
        "stale_edges", "age_max")
    assert schema.NUM_COLUMNS == 8
    assert schema.COLUMN_INDEX["step"] == 0
    assert schema.COLUMN_INDEX["age_max"] == 7
    # v2: step cells became int32-bitcast + the NODE_COLUMNS registry landed
    assert schema.SCHEMA_VERSION == 2
    assert schema.NODE_COLUMNS == (
        "step", "r", "s", "f_local", "eta_row_mean", "age_max", "alive",
        "advance", "wire_rx_bytes")
    assert schema.NUM_NODE_COLUMNS == 9
    assert schema.NODE_COLUMN_INDEX["step"] == 0
    assert schema.NODE_COLUMN_INDEX["wire_rx_bytes"] == 8


def test_unify_pads_missing_and_rejects_unregistered():
    out = schema.unify_round_metrics({"r_max": 1.0, "s_max": 2.0})
    assert tuple(out) == schema.ROUND_METRICS       # registry order
    assert float(out["stale_edges"]) == 0.0
    assert out["age_max"].dtype == np.int32         # typed zero
    with pytest.raises(ValueError, match="unregistered"):
        schema.unify_round_metrics({"r_max": 1.0, "my_new_metric": 3.0})


def test_metrics_row_roundtrips_through_row_to_dict():
    row = schema.metrics_row(7, {"r_max": 0.5, "age_max": 3})
    assert row.shape == (schema.NUM_COLUMNS,)
    d = schema.row_to_dict(np.asarray(row))
    assert d["step"] == 7 and isinstance(d["step"], int)
    assert d["age_max"] == 3 and isinstance(d["age_max"], int)
    assert d["r_max"] == pytest.approx(0.5)
    assert d["s_max"] == 0.0


def test_obs_config_validation():
    with pytest.raises(ValueError):
        ObsConfig(ring_capacity=0)
    with pytest.raises(ValueError):
        ObsConfig(drain_every=0)
    assert ObsConfig().enabled is True


# ---------------------------------------------------------- ring layer ----
def _rows(n, start=0):
    return [schema.metrics_row(start + k, {"r_max": float(start + k)})
            for k in range(n)]


def _steps(raw_rows):
    """Step ids out of raw drained rows (the cell is an int32 bitcast)."""
    return [schema.decode_step(r[schema.COLUMN_INDEX["step"]])
            for r in raw_rows]


def test_ring_drain_is_chronological_and_pure():
    ring = init_ring(8)
    for row in _rows(3):
        ring = ring_append(ring, row)
    rows, cursor, dropped = drain(ring, 0)
    assert dropped == 0 and cursor == 3
    assert _steps(rows) == [0, 1, 2]
    # pure read: same cursor -> same rows, device state untouched
    rows2, _, _ = drain(ring, 0)
    assert np.array_equal(rows, rows2)
    assert int(ring.head) == 3
    # cursor honored: nothing new since
    rows3, cursor3, _ = drain(ring, cursor)
    assert rows3.shape[0] == 0 and cursor3 == 3


def test_ring_wraparound_reports_dropped_rows():
    ring = init_ring(4)
    for row in _rows(7):                 # 7 appends into cap 4
        ring = ring_append(ring, row)
    rows, cursor, dropped = drain(ring, 0)
    assert dropped == 3                  # rows 0,1,2 overwritten
    assert cursor == 7
    # survivors are the newest cap rows, still chronological
    assert _steps(rows) == [3, 4, 5, 6]


def test_ring_append_wraps_under_jit():
    import jax

    @jax.jit
    def appends(ring):
        for row in _rows(5):
            ring = ring_append(ring, row)
        return ring

    ring = appends(init_ring(4))
    assert int(ring.head) == 5
    rows, _, dropped = drain(ring, 0)
    assert dropped == 1
    assert _steps(rows) == [1, 2, 3, 4]


def test_drain_rows_dict_form():
    ring = init_ring(4)
    ring = ring_append(ring, schema.metrics_row(9, {"age_max": 2}))
    rows, cursor, _ = drain_rows(ring, 0)
    assert cursor == 1
    assert rows[0]["step"] == 9 and rows[0]["age_max"] == 2
    assert set(rows[0]) == set(schema.RING_COLUMNS)


def test_step_stamp_exact_past_f32_significand():
    """The satellite regression pin: steps above 2^24 survive the ring.

    f32 has a 24-bit significand, so storing the step as a float VALUE
    rounds 16_777_217 to 16_777_216 (and every odd id above it to an even
    neighbor). The int32-bitcast cell (SCHEMA_VERSION 2) carries all 32
    bits exactly.
    """
    big = 16_777_216                      # 2^24: the f32 precision cliff
    steps = [big - 1, big, big + 1, big + 3]
    # the float-value encoding demonstrably cannot represent these
    assert int(np.float32(big + 1)) != big + 1
    ring = init_ring(8)
    for s in steps:
        ring = ring_append(ring, schema.metrics_row(s, {"r_max": 1.0}))
    rows, _, dropped = drain_rows(ring, 0)
    assert dropped == 0
    assert [r["step"] for r in rows] == steps
    # and the raw-cell path decodes identically
    raw, _, _ = drain(ring, 0)
    assert _steps(raw) == steps


def test_multi_wrap_drain_accumulates_dropped():
    """Drain cadence slower than the ring: rows overwritten BETWEEN drains
    are counted, cumulatively, and survivors stay chronological across
    several full wraps (drain_every > ring_capacity misconfigurations
    degrade to sampled telemetry, never to silent corruption)."""
    cap = 4
    ring = init_ring(cap)
    cursor, total_dropped, seen = 0, 0, []
    k = 0
    for burst in (6, 9, 4, 13):           # every burst > cap wraps fully
        for _ in range(burst):
            ring = ring_append(ring, schema.metrics_row(
                k, {"r_max": float(k)}))
            k += 1
        rows, cursor, dropped = drain(ring, cursor)
        total_dropped += dropped
        assert dropped == burst - cap     # the overwritten prefix, per gap
        got = _steps(rows)
        assert got == sorted(got) and len(got) == cap
        assert got[-1] == k - 1           # newest survivor is last append
        seen += got
    assert int(ring.head) == k == sum((6, 9, 4, 13))
    assert total_dropped == k - len(seen)
    assert seen == sorted(seen)           # chronological ACROSS drains too


# ----------------------------------------------------- node ring layer ----
def _slab(step, j=3, **metrics):
    return schema.node_row(step, metrics, j)


def test_node_ring_append_drain_and_dict_form():
    from repro.obs import drain_node_rows, init_node_ring, node_ring_append
    ring = init_node_ring(4, num_nodes=3)
    ring = node_ring_append(ring, _slab(
        7, r=np.array([0.1, 0.2, 0.3]), age_max=np.array([0, 2, 1]),
        alive=np.array([1.0, 1.0, 0.0])))
    ring = node_ring_append(ring, _slab(8, r=np.array([0.4, 0.5, 0.6])))
    rows, cursor, dropped = drain_node_rows(ring, 0)
    assert cursor == 2 and dropped == 0
    assert [r["step"] for r in rows] == [7, 8]
    assert set(rows[0]) == set(schema.NODE_COLUMNS)
    assert rows[0]["r"] == pytest.approx([0.1, 0.2, 0.3])
    assert rows[0]["age_max"] == [0, 2, 1]
    assert all(isinstance(v, int) for v in rows[0]["age_max"])
    assert rows[0]["alive"] == [1.0, 1.0, 0.0]
    # unreported flags pad to "everyone live and advancing" (sync path)
    assert rows[1]["alive"] == [1.0, 1.0, 1.0]
    assert rows[1]["advance"] == [1.0, 1.0, 1.0]
    assert rows[1]["s"] == [0.0, 0.0, 0.0]
    # pure read: drain again from the same cursor, same rows
    rows2, _, _ = drain_node_rows(ring, 0)
    assert rows2 == rows


def test_node_ring_wraparound_and_cursor():
    from repro.obs import drain_node_rows, init_node_ring, node_ring_append
    ring = init_node_ring(2, num_nodes=2)
    for s in range(5):
        ring = node_ring_append(ring, _slab(s, j=2,
                                            r=np.full(2, float(s))))
    rows, cursor, dropped = drain_node_rows(ring, 0)
    assert dropped == 3 and cursor == 5
    assert [r["step"] for r in rows] == [3, 4]
    assert rows[-1]["r"] == [4.0, 4.0]
    # cursor honored
    rows2, cursor2, dropped2 = drain_node_rows(ring, cursor)
    assert rows2 == [] and cursor2 == 5 and dropped2 == 0


def test_node_ring_append_under_jit():
    import jax
    from repro.obs import drain_node_rows, init_node_ring, node_ring_append

    @jax.jit
    def appends(ring):
        for s in range(3):
            ring = node_ring_append(ring, _slab(s, j=2))
        return ring

    rows, _, dropped = drain_node_rows(appends(init_node_ring(4, 2)), 0)
    assert dropped == 0 and [r["step"] for r in rows] == [0, 1, 2]


def test_unify_node_metrics_pads_and_rejects():
    out = schema.unify_node_metrics({"r": np.array([1.0, 2.0])}, 2)
    assert tuple(out) == schema.NODE_METRICS
    assert np.asarray(out["alive"]).tolist() == [1.0, 1.0]
    assert np.asarray(out["advance"]).tolist() == [1.0, 1.0]
    assert np.asarray(out["age_max"]).dtype == np.int32
    assert np.asarray(out["wire_rx_bytes"]).tolist() == [0.0, 0.0]
    with pytest.raises(ValueError, match="unregistered"):
        schema.unify_node_metrics({"r": np.zeros(2), "nope": np.zeros(2)}, 2)


# ---------------------------------------------------------- health layer ----
def _trace(j, n, r=None, eta=None, age=None, alive=None, start=0):
    """Synthetic node-row trace: per-metric callables of (step, node).

    The defaults are a CLEAN node: flat residual on the fleet median and a
    slowly drifting eta (a frozen default would trip the stall detector in
    every test) — so each test constructs exactly one anomaly.
    """
    rows = []
    for t in range(n):
        step = start + t
        rows.append({
            "step": step,
            "r": [r(t, i) if r else 1e-3 for i in range(j)],
            "s": [0.0] * j,
            "f_local": [1.0] * j,
            "eta_row_mean": [eta(t, i) if eta else 0.1 + 0.01 * (start + t)
                             for i in range(j)],
            "age_max": [age(t, i) if age else 0 for i in range(j)],
            "alive": [alive(t, i) if alive else 1.0 for i in range(j)],
            "advance": [1.0] * j,
            "wire_rx_bytes": [256.0] * j,
        })
    return rows


def test_health_divergence_fires_once_on_the_growing_node():
    from repro.obs import HealthConfig, HealthMonitor
    mon = HealthMonitor(4, HealthConfig(window=8))
    # node 2's residual doubles every round; everyone else holds flat.
    # eta drifts so the frozen-eta detector has nothing to say.
    ev = mon.observe_rows(_trace(
        4, 12,
        r=lambda t, i: 1e-3 * (2.0 ** t) if i == 2 else 1e-3,
        eta=lambda t, i: 0.1 + 0.01 * t))
    div = [e for e in ev if e["event"] == "health_divergence"]
    assert len(div) == 1                 # edge-triggered: one per episode
    assert div[0]["node"] == 2
    assert div[0]["r_late"] > 2.0 * div[0]["r_early"]
    # drift fires for node 2 as well (it IS far off the fleet median);
    # no other node trips any detector
    assert all(e["node"] == 2 for e in ev)
    assert mon.scores()[2] < mon.scores()[0] == 1.0


def test_health_eta_stall_and_oscillation_are_disjoint():
    from repro.obs import HealthConfig, HealthMonitor
    mon = HealthMonitor(4, HealthConfig(window=8))
    # node 1: eta frozen while its residual is material  -> stall
    #   (3e-3 is material vs min_residual yet under drift_ratio x median,
    #    so the stall is the ONLY thing node 1 trips)
    # node 3: eta flaps +-0.05 every round               -> oscillation
    # nodes 0/2: eta drifts monotonically, tiny residual -> clean
    ev = mon.observe_rows(_trace(
        4, 10,
        r=lambda t, i: 3e-3 if i == 1 else 1e-3,
        eta=lambda t, i: (0.1 if i == 1 else
                          0.1 + 0.05 * (t % 2) if i == 3 else
                          0.1 + 0.01 * t)))
    kinds = {}
    for e in ev:
        kinds.setdefault(e["event"], []).append(e["node"])
    assert kinds["health_eta_stall"] == [1]
    assert kinds["health_eta_oscillation"] == [3]
    assert set(kinds) == {"health_eta_stall", "health_eta_oscillation"}
    rec = mon.recommendations()
    assert rec["budget_topup"] == [1]    # stalled eta -> eq. (10) top-up
    assert any("eq. 10" in n for n in rec["notes"])


def test_health_straggler_age_and_lag_paths():
    from repro.obs import HealthConfig, HealthMonitor
    mon = HealthMonitor(4, HealthConfig(window=8), max_staleness=4)
    ev = mon.observe_rows(_trace(
        4, 8, age=lambda t, i: 3 if i == 2 else 0))
    strag = [e for e in ev if e["event"] == "health_straggler"]
    assert [e["node"] for e in strag] == [2]
    assert strag[0]["mean_age"] == pytest.approx(3.0)
    # the clock-lag path (executor summary) is independent of ages
    ev2 = mon.observe_executor({"round_lag": [0, 0, 0, 5]})
    assert [e["node"] for e in ev2] == [3]
    assert ev2[0]["lag"] == 5
    tab = mon.table()
    assert tab["nodes"][3]["lag"] == 5
    assert tab["nodes"][2]["straggler"] and tab["nodes"][3]["straggler"]


def test_health_drift_needs_no_growth_and_rearms():
    from repro.obs import HealthConfig, HealthMonitor
    mon = HealthMonitor(4, HealthConfig(window=4))
    # node 0 sits at 0.5 while the fleet median is 1e-3: drift, not
    # divergence (its residual never grows)
    ev = mon.observe_rows(_trace(
        4, 6, r=lambda t, i: 0.5 if i == 0 else 1e-3))
    assert [e["event"] for e in ev] == ["health_drift"]
    assert ev[0]["node"] == 0
    # recovery clears the verdict...
    assert mon.observe_rows(_trace(4, 6, start=6)) == []
    assert mon.scores() == [1.0] * 4
    # ...and a relapse is a NEW episode (the edge re-arms). The jump back
    # up legitimately looks like divergence too for a few rows; only the
    # drift fire COUNT is the re-arm pin.
    ev3 = mon.observe_rows(_trace(
        4, 6, r=lambda t, i: 0.5 if i == 0 else 1e-3, start=12))
    assert "health_drift" in {e["event"] for e in ev3}
    assert all(e["node"] == 0 for e in ev3)
    assert mon.table()["nodes"][0]["fires"]["drift"] == 2


def test_health_dead_nodes_render_no_verdicts():
    from repro.obs import HealthConfig, HealthMonitor
    mon = HealthMonitor(3, HealthConfig(window=4))
    # node 1 is a ghost row carrying a huge stale residual: no events, and
    # the fleet median is taken over LIVE nodes only
    ev = mon.observe_rows(_trace(
        3, 6, r=lambda t, i: 9.9 if i == 1 else 1e-3,
        alive=lambda t, i: 0.0 if i == 1 else 1.0))
    assert ev == []
    assert mon.scores() == [1.0, 1.0, 1.0]


def test_health_events_ride_the_journal_and_analyze_trace(tmp_path):
    from repro.obs import EventJournal, HealthConfig, analyze_trace
    path = str(tmp_path / "events.jsonl")
    rows = _trace(4, 8, r=lambda t, i: 3e-3 if i == 1 else 1e-3,
                  eta=lambda t, i: 0.1 if i == 1 else 0.1 + 0.01 * t)
    with EventJournal(path) as j:
        res = analyze_trace(rows, 4, cfg=HealthConfig(window=8), journal=j,
                            executor_summary={"round_lag": [0, 6, 0, 0]})
    lines = [json.loads(ln) for ln in open(path)]
    assert lines == res["events"]
    kinds = sorted(e["event"] for e in lines)
    assert kinds == ["health_eta_stall", "health_straggler"]
    assert all(e["node"] == 1 for e in lines)
    # score: 1 - 0.2 (stall) - 0.3 (straggler) = 0.5 -> not a drop
    # candidate (drop needs score < 0.5 AND a hard detector)
    assert res["table"]["nodes"][1]["score"] == pytest.approx(0.5)
    assert res["recommendations"]["drop_candidates"] == []
    assert res["recommendations"]["budget_topup"] == [1]


# ------------------------------------------------------- journal layer ----
def _topo(j=4, **kw):
    base = dict(mask=np.ones((j, j), bool), node_alive=np.ones(j, bool),
                repair=np.zeros((j, j), bool), age=np.zeros((j, j), np.int32),
                kick=np.zeros((j, j), np.float32))
    base.update(kw)
    return SimpleNamespace(**base)


def _pen(j=4, **kw):
    base = dict(eta=np.full((j, j), 0.1, np.float32),
                cum_tau=np.zeros((j, j), np.float32),
                budget=np.ones((j, j), np.float32),
                n_incr=np.zeros((j, j), np.int32))
    base.update(kw)
    return SimpleNamespace(**base)


def test_journal_diff_gate_revive_and_churn():
    prev = snapshot(_topo(), _pen())
    mask = np.ones((4, 4), bool)
    mask[0, 1] = mask[1, 0] = False      # symmetric gate
    mask[2, 3] = False                   # one-sided flip gates too: an edge
                                         # is active iff BOTH directions are
    alive = np.ones(4, bool)
    alive[3] = False
    repair = np.zeros((4, 4), bool)
    repair[1, 2] = True
    cur = snapshot(_topo(mask=mask, node_alive=alive, repair=repair), _pen())
    ev = diff_events(prev, cur, step=5)
    by = {}
    for e in ev:
        by.setdefault(e["event"], []).append(e)
    assert [e["edge"] for e in by["edge_gated"]] == [[0, 1], [2, 3]]
    assert by["edge_gated"][0]["step"] == 5
    assert by["node_dropped"][0]["node"] == 3
    assert by["repair_activated"][0]["edge"] == [1, 2]
    assert "edge_revived" not in by
    # revive is the reverse diff
    ev_back = diff_events(cur, prev, step=6)
    assert any(e["event"] == "edge_revived" and e["edge"] == [0, 1]
               for e in ev_back)


def test_journal_diff_staleness_and_kick():
    prev = snapshot(_topo(), _pen())
    age = np.zeros((4, 4), np.int32)
    age[1, 2] = 3                        # symmetrized: max(age, age.T)
    kick = np.zeros((4, 4), np.float32)
    kick[0, 3] = kick[3, 0] = 0.5
    cur = snapshot(_topo(age=age, kick=kick), _pen())
    ev = diff_events(prev, cur, step=2, max_staleness=1)
    kinds = {e["event"]: e for e in ev}
    assert kinds["stale_gated"]["edge"] == [1, 2]
    assert kinds["stale_gated"]["age"] == 3
    assert kinds["kick_parked"]["edge"] == [0, 3]
    assert kinds["kick_parked"]["weight"] == pytest.approx(0.5)
    ev_back = diff_events(cur, prev, step=3, max_staleness=1)
    kinds = {e["event"]: e for e in ev_back}
    assert kinds["stale_revived"]["edge"] == [1, 2]
    assert kinds["kick_absorbed"]["weight"] == pytest.approx(0.5)
    # without the bound there are no staleness events (executor config)
    assert not any("stale" in e["event"]
                   for e in diff_events(prev, cur, step=2))


def test_journal_diff_budget_lifecycle_is_directed():
    prev = snapshot(_topo(), _pen())
    tau = np.zeros((4, 4), np.float32)
    tau[0, 1] = 2.0                      # exhausted one direction only
    n_incr = np.zeros((4, 4), np.int32)
    n_incr[2, 0] = 1
    cur = snapshot(_topo(), _pen(cum_tau=tau, n_incr=n_incr,
                                 budget=np.full((4, 4), 1.5, np.float32)))
    ev = diff_events(prev, cur, step=9)
    kinds = {e["event"]: e for e in ev}
    assert kinds["budget_exhausted"]["edge"] == [0, 1]
    assert kinds["budget_exhausted"]["cum_tau"] == pytest.approx(2.0)
    assert kinds["budget_topup"]["edge"] == [2, 0]
    assert kinds["budget_topup"]["n_incr"] == 1
    assert sum(e["event"] == "budget_exhausted" for e in ev) == 1


def test_event_journal_baseline_and_jsonl(tmp_path):
    from repro.obs import EventJournal
    path = str(tmp_path / "events.jsonl")
    with EventJournal(path, max_staleness=1) as j:
        assert j.observe(_topo(), _pen(), step=0) == []   # baseline
        mask = np.ones((4, 4), bool)
        mask[0, 2] = mask[2, 0] = False
        ev = j.observe(_topo(mask=mask), _pen(), step=4)
        assert len(ev) == 1
        assert j.observe(_topo(mask=mask), _pen(), step=8) == []  # no diff
    lines = [json.loads(ln) for ln in open(path)]
    assert lines == [{"step": 4, "event": "edge_gated",
                      "edge": [0, 2], "eta": pytest.approx(0.1)}]


# -------------------------------------------------------- export layer ----
def test_obs_writer_artifact_set(tmp_path):
    from repro.obs import ObsWriter, validate_obs_dir
    d = str(tmp_path / "run")
    w = ObsWriter(d, meta={"wire_codec": "native",
                           "wire_bytes_per_round": 123, "offsets": [1]})
    w.append_metrics([schema.row_to_dict(np.asarray(r)) for r in _rows(3)])
    w.journal.observe(_topo(), _pen(), step=0)
    rollup = w.finalize(extra={"note": "test"})
    assert rollup["rounds"] == 3
    assert rollup["convergence"]["r_max"] == [0.0, 1.0, 2.0]
    assert rollup["wire"]["wire_bytes_per_round"] == 123
    assert rollup["note"] == "test"
    report = validate_obs_dir(d)
    assert report["ok"], report["errors"]
    assert report["files"]["metrics.jsonl"]["rows"] == 3
    # clock trace is optional, its absence is reported but not failed
    assert report["files"]["roundclock_trace.json"]["present"] is False


def _spool_run(d, *, j=3, rounds=6, drain_every=3, health=False,
               max_staleness=None):
    """Drive an ObsWriter through both rings like a launcher would."""
    import jax.numpy as jnp
    from repro.obs import (ObsWriter, init_node_ring, init_ring,
                           node_ring_append, ring_append)
    w = ObsWriter(d, meta={"wire_codec": "native",
                           "wire_bytes_per_round": 64, "offsets": [1]},
                  health=health, max_staleness=max_staleness)
    state = SimpleNamespace(ring=init_ring(8),
                            node_ring=init_node_ring(8, num_nodes=j),
                            topo=_topo(j), penalty=_pen(j))
    for s in range(rounds):
        state.ring = ring_append(state.ring, schema.metrics_row(
            s, {"r_max": 0.1 / (s + 1), "s_max": 0.05, "f_mean": 1.0,
                "eta_mean": 0.1}))
        state.node_ring = node_ring_append(state.node_ring, schema.node_row(
            s, {"r": jnp.full((j,), 0.1 / (s + 1)),
                "eta_row_mean": jnp.full((j,), 0.1),
                "wire_rx_bytes": jnp.full((j,), 64.0)}, j))
        if (s + 1) % drain_every == 0:
            w.drain(state, step=s)
    w.drain(state, step=rounds)
    return w


def test_obs_writer_spools_node_metrics_timing_and_health(tmp_path):
    from repro.obs import validate_obs_dir
    d = str(tmp_path / "run")
    w = _spool_run(d, health=True)
    w.observe_executor({"rounds_done": [6, 6, 5], "round_lag": [0, 0, 1],
                        "lag_p50": 0, "lag_p90": 1, "lag_p100": 1})
    rollup = w.finalize()
    assert rollup["rounds"] == 6
    # satellite pin: host wall-clock per drain -> rollup round_ms. The
    # first drain only anchors the clock; the second covers 3 rounds.
    t = rollup["timing"]
    assert t["drains"] == 1 and t["round_ms"] >= 0.0
    assert set(t) >= {"drains", "round_ms", "round_ms_p50", "round_ms_max"}
    pn = rollup["per_node"]
    assert pn["num_nodes"] == 3 and pn["rounds"] == 6
    assert pn["dropped_rows"] == 0
    assert pn["wire_rx_bytes_total"] == pytest.approx([6 * 64.0] * 3)
    # health table + advisory block land in the rollup when --health is on
    assert rollup["health"]["rows_seen"] == 6
    assert len(rollup["health"]["nodes"]) == 3
    assert "recommendations" in rollup["health"]
    assert rollup["executor"]["lag_p100"] == 1
    report = validate_obs_dir(d)
    assert report["ok"], report["errors"]
    assert report["files"]["node_metrics.jsonl"]["rows"] == 6
    rows = [json.loads(ln) for ln in open(os.path.join(
        d, "node_metrics.jsonl"))]
    assert set(rows[0]) == set(schema.NODE_COLUMNS)
    assert rows[0]["step"] == 0 and len(rows[0]["r"]) == 3


def test_obs_writer_without_node_ring_stays_valid(tmp_path):
    """A scalar-only run (with_node_ring=False) writes no node artifacts
    and the validator treats their absence as fine, not as an error."""
    from repro.obs import ObsWriter, init_ring, ring_append, validate_obs_dir
    d = str(tmp_path / "run")
    w = ObsWriter(d, meta={"wire_codec": "native",
                           "wire_bytes_per_round": 64, "offsets": [1]})
    state = SimpleNamespace(ring=init_ring(8), node_ring=None,
                            topo=_topo(), penalty=_pen())
    state.ring = ring_append(state.ring, schema.metrics_row(
        0, {"r_max": 0.1}))
    w.drain(state, step=0)
    rollup = w.finalize()
    assert rollup["per_node"] == {}
    report = validate_obs_dir(d)
    assert report["ok"], report["errors"]
    assert report["files"]["node_metrics.jsonl"]["present"] is False


# ------------------------------------------------------ dashboard layer ----
def test_dashboard_renders_and_self_checks(tmp_path):
    from repro.obs.dashboard import check_dashboard, render_dashboard
    d = str(tmp_path / "run")
    w = _spool_run(d, health=True, max_staleness=4)
    w.journal.emit({"step": 3, "event": "edge_gated", "edge": [0, 1]})
    w.finalize()
    path = render_dashboard(d)
    assert path == os.path.join(d, "dashboard.html")
    report = check_dashboard(path)
    assert report["ok"], report["errors"]
    # the run had node rows, so the per-node heatmaps must be promised
    assert {"residuals", "node_r", "events", "health_table"} <= set(
        report["series"])
    html = open(path).read()
    assert "<svg" in html and "dash-manifest" in html
    # self-contained: nothing in the page references a remote resource
    # (the SVG xmlns namespace URI is an identifier, not a fetch)
    for needle in ('src="http', "src='http", 'href="http', "href='http",
                   "url(http", "@import", "fetch("):
        assert needle not in html, needle


def test_dashboard_check_catches_a_dropped_section(tmp_path):
    from repro.obs.dashboard import check_dashboard, render_dashboard
    d = str(tmp_path / "run")
    _spool_run(d).finalize()
    path = render_dashboard(d)
    html = open(path).read()
    with open(path, "w") as f:                # silently drop one section
        f.write(html.replace('id="series-node_r"', 'id="series-oops"'))
    report = check_dashboard(path)
    assert not report["ok"]
    assert any("node_r" in e for e in report["errors"])


def test_dashboard_cli_roundtrip(tmp_path):
    from repro.obs.dashboard import main
    d = str(tmp_path / "run")
    _spool_run(d).finalize()
    out = str(tmp_path / "dash.html")
    assert main([d, "-o", out, "--check"]) == 0
    assert os.path.exists(out)


def test_validator_fails_on_missing_and_malformed(tmp_path):
    from repro.obs import validate_obs_dir
    d = str(tmp_path / "broken")
    os.makedirs(d)
    report = validate_obs_dir(d)
    assert not report["ok"]
    assert any("metrics.jsonl: missing" in e for e in report["errors"])
    # a metrics row missing schema keys is an error too
    for name in ("run.json", "rollup.json"):
        with open(os.path.join(d, name), "w") as f:
            json.dump({"rounds": 0, "convergence": {}, "staleness": {}}, f)
    with open(os.path.join(d, "events.jsonl"), "w"):
        pass
    with open(os.path.join(d, "metrics.jsonl"), "w") as f:
        f.write(json.dumps({"step": 1}) + "\n")
    report = validate_obs_dir(d)
    assert any("missing keys" in e for e in report["errors"])


def test_roundclock_perfetto_reconstruction(tmp_path):
    from repro.async_exec import RoundClock, straggler_compute
    from repro.obs import roundclock_trace_events, write_roundclock_trace
    clock = RoundClock(compute_s=straggler_compute(3, factor=2.0),
                       wire_s=0.25, offsets=(1,))
    for _ in range(4):
        clock.tick()
    ev = roundclock_trace_events(clock)
    spans = [e for e in ev if e["ph"] == "X" and e["cat"] == "compute"]
    wires = [e for e in ev if e["ph"] == "X" and e["cat"] == "wire"]
    ticks = [e for e in ev if e["ph"] == "i"]
    assert len(spans) == int(np.sum(clock.rounds_done))
    assert len(wires) == len(spans)      # every round sends once
    assert len(ticks) == 4
    # straggler node 0 rounds are 2x wide; sends start at round end
    w0 = [e for e in spans if e["tid"] == 0][0]
    w1 = [e for e in spans if e["tid"] == 1][0]
    assert w0["dur"] == pytest.approx(2 * w1["dur"])
    s1 = [e for e in wires if e["tid"] == 3 + 1][0]
    assert s1["ts"] == pytest.approx(w1["ts"] + w1["dur"])
    path = write_roundclock_trace(clock, str(tmp_path / "t.json"))
    doc = json.load(open(path))
    assert doc["traceEvents"] and doc["otherData"]["tick_s"] == clock.tick_s


# ----------------------------------------------- engine layer (8 dev) ----
_ENGINE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
from repro.async_exec import AsyncConfig, AsyncExecutor
from repro.configs import get_reduced_config
from repro.core.penalty import PenaltyConfig
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.obs import ObsConfig
from repro.obs import node_ring as node_ring_lib
from repro.obs import ring as ring_lib
from repro.obs import schema
from repro.optim import ConsensusConfig, ConsensusTrainer
from repro.optim.adamw import AdamWConfig
from repro.topology import TopologyConfig

out = {}
mesh = make_mesh((4, 2, 1), ("pod", "data", "model"))
cfg = get_reduced_config("qwen3-4b")
model = build_model(cfg)
data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  batch_per_node=2, num_nodes=4))
probe = data.batch(0, probe=True)

def make(obs=None, async_cfg=None, sharded=False, pipe=1):
    return ConsensusTrainer(
        model, mesh, adamw=AdamWConfig(lr=1e-2),
        consensus=ConsensusConfig(
            penalty=PenaltyConfig(scheme="nap", eta0=0.1),
            topology="ring", local_steps=1,
            dyn_topology=TopologyConfig(),
            async_exec=async_cfg, shard_consensus=sharded,
            pipeline_offsets=pipe, obs=obs))

# --- 1. obs off leaves ZERO footprint: byte-identical HLO ---------------
hlo = {}
for tag, obs in (("none", None), ("disabled", ObsConfig(enabled=False)),
                 ("scalar_only", ObsConfig(ring_capacity=8,
                                           with_node_ring=False)),
                 ("enabled", ObsConfig(ring_capacity=8))):
    tr = make(obs=obs)
    st = tr.init_state(jax.random.PRNGKey(0))
    hlo[tag] = jax.jit(tr.consensus_step).lower(st, probe).as_text()
    if tag in ("none", "disabled"):
        out[f"ring_is_none_{tag}"] = st.ring is None
    out[f"node_ring_is_none_{tag}"] = st.node_ring is None
out["hlo_off_byte_identical"] = hlo["none"] == hlo["disabled"]
out["hlo_enabled_differs"] = hlo["none"] != hlo["enabled"]
# with_node_ring=False compiles the node ring OUT: the program differs
# from the full telemetry plane but still carries the scalar ring
out["hlo_scalar_only_differs_from_enabled"] = (
    hlo["scalar_only"] != hlo["enabled"])
out["hlo_scalar_only_differs_from_off"] = hlo["scalar_only"] != hlo["none"]
out["hlo_enabled_has_ring_write"] = (
    "dynamic_update_slice" in hlo["enabled"]        # stablehlo spelling
    or "dynamic-update-slice" in hlo["enabled"])    # hlo spelling

# --- 2. ring under the REAL jitted step fns (donation path) -------------
results = {}
for tag, kw in (("sync", {}), ("sharded", {"sharded": True}),
                ("pipelined", {"pipe": 4})):
    tr = make(obs=ObsConfig(ring_capacity=8), **kw)
    st = tr.init_state(jax.random.PRNGKey(0))
    train, cons = tr.jit_step_fns()
    for s in range(3):      # launcher cadence: train step then round, so
        st, m = train(st, data.batch(s))        # the stamped steps differ
        st, m = cons(st, data.batch(s, probe=True))
    rows, cursor, dropped = ring_lib.drain_rows(st.ring, 0)
    out[f"{tag}_ring_rows"] = len(rows)
    out[f"{tag}_ring_dropped"] = dropped
    out[f"{tag}_ring_steps"] = [r["step"] for r in rows]
    out[f"{tag}_keys"] = sorted(m)
    nrows, _, ndropped = node_ring_lib.drain_node_rows(st.node_ring, 0)
    results[tag] = (rows, m, nrows)
    out[f"{tag}_node_rows"] = len(nrows)
    out[f"{tag}_node_dropped"] = ndropped
    out[f"{tag}_node_steps"] = [r["step"] for r in nrows]
    out[f"{tag}_node_keys"] = sorted(nrows[0]) if nrows else []
    out[f"{tag}_node_r"] = [r["r"] for r in nrows]
    out[f"{tag}_node_alive"] = nrows[-1]["alive"] if nrows else []
    out[f"{tag}_node_rx"] = nrows[-1]["wire_rx_bytes"] if nrows else []

# value-consistency pin: the sharded engine's per-node residuals are the
# post-psum replicated values — identical to the replicated engine's up
# to float reassociation
out["node_sync_sharded_r_close"] = bool(np.allclose(
    np.asarray(out["sync_node_r"]), np.asarray(out["sharded_node_r"]),
    rtol=1e-2, atol=1e-3))
# round-pipeline pin: pipelining is a pure reordering, so the node ring's
# telemetry — wire_rx accounting included — is EXACTLY the sequential
# engine's, row for row
out["node_pipelined_rows_equal_sync"] = (
    results["pipelined"][2] == results["sync"][2])
out["ring_pipelined_rows_equal_sync"] = (
    results["pipelined"][0] == results["sync"][0])

# --- 3. async executor rounds append too, same key set ------------------
tra = make(obs=ObsConfig(ring_capacity=8),
           async_cfg=AsyncConfig(max_staleness=1))
sta = tra.init_state(jax.random.PRNGKey(0))
train_a = tra.jit_step_fns()[0]
sta, _ = train_a(sta, data.batch(0))
ex = AsyncExecutor(tra)
for s in range(1, 4):
    sta, ma = ex.consensus_round(sta, probe)
rows_a, _, _ = ring_lib.drain_rows(sta.ring, 0)
out["async_ring_rows"] = len(rows_a)
out["async_keys"] = sorted(ma)
nrows_a, _, _ = node_ring_lib.drain_node_rows(sta.node_ring, 0)
out["async_node_rows"] = len(nrows_a)
out["async_node_keys"] = sorted(nrows_a[0]) if nrows_a else []
out["async_node_alive"] = nrows_a[-1]["alive"] if nrows_a else []
out["async_node_advance"] = nrows_a[-1]["advance"] if nrows_a else []
out["async_node_ages_ok"] = all(
    isinstance(v, int) and 0 <= v <= 1
    for r in nrows_a for v in r["age_max"])
out["schema_keys"] = sorted(schema.ROUND_METRICS)
out["node_schema_keys"] = sorted(schema.NODE_COLUMNS)
out["row_keys_match_schema"] = all(
    set(r) == set(schema.RING_COLUMNS) for r in results["sync"][0] + rows_a)
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def engine_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _ENGINE], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_obs_off_is_byte_identical_hlo(engine_results):
    """Acceptance pin: with obs unset (or enabled=False) the compiled
    consensus step is BYTE-IDENTICAL to a build that never heard of obs —
    no ring in the state, no spans in the HLO metadata, nothing."""
    assert engine_results["hlo_off_byte_identical"] is True
    assert engine_results["ring_is_none_none"] is True
    assert engine_results["ring_is_none_disabled"] is True


def test_obs_enabled_adds_exactly_the_ring_write(engine_results):
    assert engine_results["hlo_enabled_differs"] is True
    assert engine_results["hlo_enabled_has_ring_write"] is True


def test_ring_appends_under_jit_and_donation(engine_results):
    """The jitted (donating) step fns append one stamped row per round on
    both the replicated and the sharded engine; the pure-read drain sees
    them all."""
    for tag in ("sync", "sharded"):
        assert engine_results[f"{tag}_ring_rows"] == 3
        assert engine_results[f"{tag}_ring_dropped"] == 0
        steps = engine_results[f"{tag}_ring_steps"]
        assert steps == sorted(steps) and len(set(steps)) == 3
    assert engine_results["async_ring_rows"] == 3


def test_metrics_key_set_is_unified(engine_results):
    """The metrics-shape-drift satellite pin: sync, sharded and async
    rounds all emit exactly the registered ROUND_METRICS key set."""
    want = engine_results["schema_keys"]
    assert engine_results["sync_keys"] == want
    assert engine_results["sharded_keys"] == want
    assert engine_results["async_keys"] == want
    assert engine_results["row_keys_match_schema"] is True


def test_node_ring_compiles_out_when_gated(engine_results):
    """``with_node_ring=False`` removes the node ring from the state AND
    from the compiled program, while the scalar ring stays."""
    for tag in ("none", "disabled", "scalar_only"):
        assert engine_results[f"node_ring_is_none_{tag}"] is True
    assert engine_results["node_ring_is_none_enabled"] is False
    assert engine_results["hlo_scalar_only_differs_from_enabled"] is True
    assert engine_results["hlo_scalar_only_differs_from_off"] is True


def test_node_ring_appends_on_every_engine(engine_results):
    """One [J, NUM_NODE_COLUMNS] slab per round on the replicated, sharded
    AND async engines, stamped with the same steps as the scalar ring."""
    for tag in ("sync", "sharded"):
        assert engine_results[f"{tag}_node_rows"] == 3
        assert engine_results[f"{tag}_node_dropped"] == 0
        assert (engine_results[f"{tag}_node_steps"]
                == engine_results[f"{tag}_ring_steps"])
        assert (engine_results[f"{tag}_node_keys"]
                == engine_results["node_schema_keys"])
        assert len(engine_results[f"{tag}_node_r"][0]) == 4      # J
        # a static sync round: every node alive, every node consumed wire
        assert engine_results[f"{tag}_node_alive"] == [1.0] * 4
        assert all(v > 0 for v in engine_results[f"{tag}_node_rx"])
    assert engine_results["async_node_rows"] == 3
    assert (engine_results["async_node_keys"]
            == engine_results["node_schema_keys"])
    assert engine_results["async_node_alive"] == [1.0] * 4
    assert all(v in (0.0, 1.0)
               for v in engine_results["async_node_advance"])
    assert engine_results["async_node_ages_ok"] is True


def test_node_ring_unchanged_under_pipelining(engine_results):
    """Round-pipeline satellite pin: with ``pipeline_offsets=4`` the node
    ring's drained rows — per-node residuals, liveness, and the wire_rx
    byte accounting — are EXACTLY the sequential engine's (pipelining
    reorders the schedule, never the values or the telemetry), and the
    scalar ring matches row for row too."""
    assert engine_results["node_pipelined_rows_equal_sync"] is True
    assert engine_results["ring_pipelined_rows_equal_sync"] is True
    assert engine_results["pipelined_node_rows"] == 3
    assert all(v > 0 for v in engine_results["pipelined_node_rx"])


def test_node_residuals_sharded_equals_replicated(engine_results):
    """The acceptance pin: the sharded engine's node rows carry the
    post-psum replicated residuals — value-consistent with the replicated
    engine on the same seed/data."""
    assert engine_results["node_sync_sharded_r_close"] is True
