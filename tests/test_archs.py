"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs. Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, cells, get_config, \
    get_reduced_config
from repro.configs.base import ShapeCell
from repro.models import build_model, input_specs, make_batch

SMOKE = ShapeCell("smoke", 32, 2, "train")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_reduced_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, SMOKE, jax.random.PRNGKey(1))

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: m.loss(q, b), has_aux=True)(p)
        return loss, grads

    loss, grads = step(params, batch)
    assert np.isfinite(float(loss)), arch
    gleaves = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
               for g in gleaves), arch
    # grads reach every parameter (scan stacking kept everything wired)
    nonzero = sum(float(jnp.abs(g).sum()) > 0 for g in gleaves)
    assert nonzero >= len(gleaves) - 2, (arch, nonzero, len(gleaves))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_reduced_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, MAXLEN = 2, 16
    st = m.init_decode_state(B, MAXLEN)
    if cfg.frontend != "none":
        emb = jnp.ones((B, cfg.d_model), jnp.float32)
        logits, st = jax.jit(lambda p, s: m.decode_step(
            p, s, None, max_len=MAXLEN, embed_in=emb))(params, st)
    else:
        tok = jnp.zeros((B,), jnp.int32)
        logits, st = jax.jit(lambda p, s, t: m.decode_step(
            p, s, t, max_len=MAXLEN))(params, st, tok)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
    assert int(st.pos) == 1


@pytest.mark.parametrize("arch", ["qwen3-4b", "glm4-9b", "rwkv6-7b",
                                  "hymba-1.5b"])
def test_prefill_decode_consistency(arch):
    """Step-by-step decode must reproduce the full-sequence forward."""
    cfg = get_reduced_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    T = 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, T), 0, cfg.vocab)
    ref = m.prefill(params, {"tokens": toks})
    st = m.init_decode_state(1, T)
    step = jax.jit(lambda p, s, t: m.decode_step(p, s, t, max_len=T))
    outs = []
    for i in range(T):
        lg, st = step(params, st, toks[:, i])
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    err = float(jnp.max(jnp.abs(dec.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < 0.15, (arch, err)  # bf16 accumulation tolerance


def test_full_configs_match_assignment():
    """The registered full configs carry the exact assigned hyperparams."""
    expect = {
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
    }
    for arch, (l, d, h, kv, ff, v) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab) == (l, d, h, kv, ff, v), arch


def test_moe_configs():
    k = get_config("kimi-k2-1t-a32b").moe
    assert (k.num_experts, k.top_k) == (384, 8)
    m = get_config("moonshot-v1-16b-a3b").moe
    assert (m.num_experts, m.top_k) == (64, 6)
    # ~1T total / ~32B active sanity
    from repro.models import build_model
    km = build_model(get_config("kimi-k2-1t-a32b"))
    assert 0.9e12 < km.param_count() < 1.2e12
    assert 25e9 < km.active_param_count() < 40e9


def test_cells_cover_assignment():
    cs = list(cells())
    assert len(cs) == 40
    skipped = [(c.arch_id, s.name) for c, s, sk in cs if sk]
    # exactly the 8 full-attention archs skip long_500k
    assert len(skipped) == 8
    assert all(name == "long_500k" for _, name in skipped)
    assert ("rwkv6-7b", "long_500k") not in skipped
    assert ("hymba-1.5b", "long_500k") not in skipped


def test_input_specs_shapes():
    cfg = get_config("qwen3-4b")
    s = input_specs(cfg, SHAPES["train_4k"])
    assert s["tokens"].shape == (256, 4096)
    s = input_specs(cfg, SHAPES["decode_32k"])
    assert s["token"].shape == (128,)
    cfg = get_config("musicgen-large")   # frontend stub: embeddings in
    s = input_specs(cfg, SHAPES["prefill_32k"])
    assert s["embeds"].shape == (32, 32768, 2048)


def test_sliding_window_attention_masks_correctly():
    from repro.models.attention import flash_ref, chunked_causal_attention
    rng = np.random.default_rng(0)
    b, s, h, hd = 1, 64, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    full = flash_ref(q, k, v, causal=True, window=16)
    chunked = chunked_causal_attention(q, k, v, window=16, chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               atol=2e-5)


def test_chunked_attention_equals_naive():
    from repro.models.attention import flash_ref, chunked_causal_attention
    rng = np.random.default_rng(1)
    b, s, h, hd = 2, 128, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    naive = flash_ref(q, k, v, causal=True)
    chunked = chunked_causal_attention(q, k, v, chunk=32)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(naive),
                               atol=2e-5)
