"""Tests for the dynamic-topology runtime (repro.topology).

Three layers:
  * property tests — every scheduler's masked graph stays connected every
    epoch (incl. across node churn), epochs/liveness invariants;
  * dense-path behavior — budget-gated NAP matches fixed-topology NAP on
    the paper's J=12 synthetic least-squares problem (iterations-to-
    converge under the paper's §5 relative-objective criterion) for ring
    and cluster, then sheds edges post-convergence without hurting error;
  * engine pins (subprocess, 8 fake devices) — scheduler="static" is
    bit-identical to the PR 1 fused round, and a mid-run node drop on the
    debug mesh completes training without recompiling the fused step.
"""
import json
import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (ConsensusADMM, PenaltyConfig, build_graph,
                        connected_components, init_penalty_state)
from repro.topology import (SCHEDULERS, TopologyConfig, TopologyRuntime,
                            spanning_backbone)

from proptest import sweep, draw_topology

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _alive_components(mask, alive):
    m = np.asarray(mask) & alive[:, None] & alive[None, :]
    return [c for c in connected_components(m) if alive[c[0]]]


# ------------------------------------------------------- property layer ----
def test_backbone_spans_every_topology():
    def prop(rng, i):
        j = int(rng.integers(2, 16))
        g = build_graph(draw_topology(rng, j), j)
        bb = spanning_backbone(g)
        assert not np.any(bb & ~g.adj), "backbone must be a subgraph"
        assert len(connected_components(bb)) == 1
    sweep(prop, cases=20, seed=11)


@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_scheduler_masks_stay_connected_every_epoch(scheduler):
    """The headline invariant: mask ⊇ backbone ⇒ connected, symmetric,
    diagonal-free — for every scheduler, topology, and epoch."""
    def prop(rng, i):
        j = int(rng.integers(3, 12))
        g = build_graph(draw_topology(rng, j), j)
        rt = TopologyRuntime(g, TopologyConfig(
            scheduler=scheduler, churn=True, seed=i,
            activation_p=float(rng.uniform(0.1, 0.9))))
        st = rt.init_state()
        pen = init_penalty_state(PenaltyConfig(scheme="nap"), j)
        # drive the budget gate hard: pretend everything is exhausted+close
        pen = pen._replace(cum_tau=pen.budget + 1.0)
        for t in range(6):
            st = rt.update(st, penalty=pen, r_norm=jnp.zeros(j))
            m = np.asarray(st.mask)
            assert np.array_equal(m, m.T), (scheduler, t)
            assert not m.diagonal().any(), (scheduler, t)
            assert not np.any(m & ~(np.asarray(st.backbone)
                                    | np.asarray(st.repair) | g.adj))
            comps = _alive_components(m, np.ones(j, bool))
            assert len(comps) == 1, (scheduler, t, comps)
    sweep(prop, cases=10, seed=13)


@pytest.mark.parametrize("scheduler", SCHEDULERS)
def test_masks_stay_connected_across_churn(scheduler):
    """Dense-universe churn (repair may use any pair): drop nodes down to
    two survivors; the masked graph must stay connected at every epoch."""
    def prop(rng, i):
        j = int(rng.integers(4, 12))
        g = build_graph(draw_topology(rng, j), j)
        rt = TopologyRuntime(g, TopologyConfig(scheduler=scheduler,
                                               churn=True, seed=i),
                             edge_universe=~np.eye(j, dtype=bool))
        st = rt.init_state()
        pen = init_penalty_state(PenaltyConfig(scheme="nap"), j)
        alive = np.ones(j, bool)
        victims = rng.permutation(j)[: j - 2]
        for v in victims:
            st = rt.drop_node(st, int(v))
            alive[int(v)] = False
            st = rt.update(st, penalty=pen, r_norm=jnp.zeros(j))
            m = np.asarray(st.mask)
            assert not m[int(v)].any() and not m[:, int(v)].any()
            assert np.array_equal(np.asarray(st.node_alive), alive)
            comps = _alive_components(m, alive)
            assert len(comps) == 1, (scheduler, int(v), comps)
    sweep(prop, cases=8, seed=17)


def test_single_drop_repairable_within_engine_offset_superset():
    """Engine-universe churn: one node loss must always be repairable
    through the compiled circulant offset superset."""
    def prop(rng, i):
        j = int(rng.integers(4, 14))
        g = build_graph(draw_topology(rng, j), j)
        rt = TopologyRuntime(g, TopologyConfig(scheduler="static",
                                               churn=True))
        st = rt.drop_node(rt.init_state(), int(rng.integers(0, j)))
        alive = np.asarray(st.node_alive)
        comps = _alive_components(np.asarray(st.mask), alive)
        assert len(comps) == 1, comps
    sweep(prop, cases=20, seed=19)


def test_budget_gate_latches_and_revives_on_topup():
    j = 6
    g = build_graph("complete", j)
    rt = TopologyRuntime(g, TopologyConfig(scheduler="budget",
                                           gate_tol=1e-2))
    st = rt.init_state()
    pen = init_penalty_state(PenaltyConfig(scheme="nap"), j)
    # exhaust every budget, residuals below tolerance -> non-backbone gated
    pen_exh = pen._replace(cum_tau=pen.budget + 1.0)
    st = rt.update(st, penalty=pen_exh, r_norm=jnp.zeros(j))
    gated = np.asarray(~st.mask & g.adj)
    assert gated.any(), "nothing gated"
    # residuals drift back up: the latch must hold while exhausted
    st2 = rt.update(st, penalty=pen_exh, r_norm=jnp.full(j, 1e3))
    assert np.array_equal(np.asarray(st.mask), np.asarray(st2.mask))
    # top-up (budget above cum_tau) revives everything
    pen_rev = pen_exh._replace(budget=pen_exh.cum_tau + 1.0)
    st3 = rt.update(st2, penalty=pen_rev, r_norm=jnp.full(j, 1e3))
    assert np.array_equal(np.asarray(st3.mask), g.adj)
    # epochs counted each flip
    assert np.asarray(st3.epoch)[gated].min() >= 2


def test_stale_scheduler_gates_on_age_and_revives_on_arrival():
    """The async executor's scheduler: edges deactivate while either
    direction's payload age exceeds the bound and revive (no latch) the
    epoch a fresh payload resets the clock."""
    from repro.topology import tick_age
    j = 6
    g = build_graph("complete", j)
    rt = TopologyRuntime(g, TopologyConfig(scheduler="stale",
                                           max_staleness=1))
    st = rt.init_state()
    pen = init_penalty_state(PenaltyConfig(scheme="nap"), j)
    # ages zero -> degenerates to static
    st = rt.update(st, penalty=pen, r_norm=jnp.zeros(j))
    assert np.array_equal(np.asarray(st.mask), g.adj)
    # node 0's payloads stop arriving: after 2 stale ticks its non-backbone
    # edges gate (one direction aging is enough — sym_age is the max)
    fresh = np.ones((j, j), bool)
    fresh[:, 0] = False
    for _ in range(2):
        st = tick_age(st, jnp.asarray(fresh))
    st = rt.update(st, penalty=pen, r_norm=jnp.zeros(j))
    m = np.asarray(st.mask)
    bb = np.asarray(st.backbone)
    assert not m[0, 2:-1].any()                   # chords to node 0 gated
    assert np.array_equal(m, m.T)
    assert (m & ~bb)[1:, 1:].any()                # other edges untouched
    assert np.array_equal(m | bb, m)              # backbone subset of mask
    # a fresh arrival resets the clocks -> full revival, no latch
    st = tick_age(st, jnp.asarray(np.ones((j, j), bool)))
    st = rt.update(st, penalty=pen, r_norm=jnp.zeros(j))
    assert np.array_equal(np.asarray(st.mask), g.adj)


def test_round_robin_rotates_and_random_is_deterministic():
    j = 8
    g = build_graph("complete", j)
    pen = init_penalty_state(PenaltyConfig(scheme="nap"), j)
    rt = TopologyRuntime(g, TopologyConfig(scheduler="round_robin"))
    st = rt.init_state()
    masks = []
    for _ in range(3):
        st = rt.update(st, penalty=pen, r_norm=jnp.zeros(j))
        masks.append(np.asarray(st.mask))
    assert not np.array_equal(masks[0], masks[1])  # rotation moved
    rt2 = TopologyRuntime(g, TopologyConfig(scheduler="random", seed=3))
    a = rt2.update(rt2.init_state(), penalty=pen, r_norm=jnp.zeros(j))
    b = rt2.update(rt2.init_state(), penalty=pen, r_norm=jnp.zeros(j))
    assert np.array_equal(np.asarray(a.mask), np.asarray(b.mask))


def test_drop_node_star_cut_vertex_chains_all_components():
    """Satellite bugfix pin: dropping the hub of a star-like cut region
    must reconnect ALL resulting components (>2 of them)."""
    from repro.core import Graph, drop_node
    j = 7
    adj = np.zeros((j, j), bool)
    for leaf in range(1, j):            # star: 0 is a cut vertex of 6 leaves
        adj[0, leaf] = adj[leaf, 0] = True
    g = Graph(j, adj, "star")
    g2 = drop_node(g, 0)
    assert g2.num_nodes == j - 1
    assert g2.is_connected()
    # spanning chain over components: exactly components-1 = 5 bridges
    assert g2.num_edges == j - 2


def test_expected_active_fraction_bounds():
    g = build_graph("complete", 10)
    for sched in SCHEDULERS:
        rt = TopologyRuntime(g, TopologyConfig(scheduler=sched))
        f = rt.expected_active_fraction()
        assert 0.0 < f <= 1.0, (sched, f)
    assert TopologyRuntime(
        g, TopologyConfig()).expected_active_fraction() == 1.0


# ----------------------------------------------------- dense-path layer ----
def _lsq_problem(j, d=4, n=16, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(j, n, d)).astype(np.float32)
    w_true = rng.normal(size=d).astype(np.float32)
    b = A @ w_true + 0.01 * rng.normal(size=(j, n)).astype(np.float32)
    w_star = np.linalg.lstsq(A.reshape(-1, d), b.reshape(-1), rcond=None)[0]
    theta0 = {"w": jnp.asarray(rng.normal(size=(j, d)).astype(np.float32))}
    return (jnp.asarray(A), jnp.asarray(b)), theta0, w_star


def _lsq_obj(data, th):
    Ai, bi = data
    return jnp.sum((Ai @ th["w"] - bi) ** 2)


@pytest.mark.parametrize("topo", ["ring", "cluster"])
def test_budget_matches_fixed_topology_nap_iterations(topo):
    """Acceptance pin: budget-gated NAP converges in <= the iterations of
    fixed-topology NAP on the J=12 synthetic problem (paper §5 criterion),
    with the SAME trajectory while no edge is gated."""
    j = 12
    data, theta0, w_star = _lsq_problem(j, seed=3)
    iters = {}
    for label, tcfg in (("fixed", None),
                        ("budget", TopologyConfig(scheduler="budget"))):
        eng = ConsensusADMM(objective=_lsq_obj,
                            penalty_cfg=PenaltyConfig(scheme="nap", eta0=1.0),
                            graph=build_graph(topo, j),
                            inner_steps=30, inner_lr=1.0, topology_cfg=tcfg)
        st = eng.init(theta0)
        st, hist = eng.run(st, data, max_iters=400, rel_tol=1e-3)
        iters[label] = hist["iterations"]
        err = np.abs(np.asarray(st.theta["w"]) - w_star).max()
        assert err < 0.05, (topo, label, err)
    assert iters["budget"] <= iters["fixed"], iters


def test_budget_sheds_edges_post_convergence_without_drift():
    """§4 realized: once locally converged, exhausted edges detach — wire
    drops while the iterate stays at the consensus solution."""
    j = 12
    data, theta0, w_star = _lsq_problem(j, seed=3)
    eng = ConsensusADMM(objective=_lsq_obj,
                        penalty_cfg=PenaltyConfig(scheme="nap", eta0=1.0),
                        graph=build_graph("complete", j),
                        inner_steps=30, inner_lr=1.0,
                        topology_cfg=TopologyConfig(scheduler="budget"))
    st = eng.init(theta0)
    st, _ = eng.run(st, data, max_iters=400, rel_tol=1e-3)
    for _ in range(100):
        st, m = eng.step(st, data)
    active = float(np.asarray(st.topo.mask).sum()
                   / max(build_graph("complete", j).adj.sum(), 1))
    assert active < 0.5, active                 # most edges shed
    err = np.abs(np.asarray(st.theta["w"]) - w_star).max()
    assert err < 0.01, err                      # iterate stayed put
    comps = connected_components(np.asarray(st.topo.mask))
    assert len(comps) == 1                      # backbone held


def test_dense_node_drop_mid_run_recovers():
    j = 8
    data, theta0, w_star = _lsq_problem(j, seed=5)
    eng = ConsensusADMM(objective=_lsq_obj,
                        penalty_cfg=PenaltyConfig(scheme="nap", eta0=1.0),
                        graph=build_graph("ring", j),
                        inner_steps=30, inner_lr=1.0,
                        topology_cfg=TopologyConfig(scheduler="static",
                                                    churn=True))
    st = eng.init(theta0)
    for _ in range(10):
        st, _ = eng.step(st, data)
    st = eng.apply_churn(st, 3)
    for _ in range(150):
        st, m = eng.step(st, data)
    alive = np.asarray(st.topo.node_alive)
    w = np.asarray(st.theta["w"])[alive]
    # survivors reach consensus among themselves (node 3's data is gone,
    # so the solution is the SURVIVORS' least-squares, not w_star)
    assert np.abs(w - w.mean(axis=0)).max() < 0.05


# ------------------------------------------------ engine layer (8 dev) ----
_ENGINE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_reduced_config
from repro.core.penalty import PenaltyConfig
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.optim import ConsensusConfig, ConsensusTrainer
from repro.optim.adamw import AdamWConfig
from repro.topology import TopologyConfig

out = {}
mesh = make_mesh((4, 2, 1), ("pod", "data", "model"))
cfg = get_reduced_config("qwen3-4b")
model = build_model(cfg)
data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  batch_per_node=2, num_nodes=4))

def make(dyn, fused=True, topology="ring"):
    return ConsensusTrainer(
        model, mesh, adamw=AdamWConfig(lr=1e-2),
        consensus=ConsensusConfig(
            penalty=PenaltyConfig(scheme="nap", eta0=0.1),
            topology=topology, local_steps=1, use_fused_kernel=fused,
            dyn_topology=dyn))

base = make(TopologyConfig())                  # PR 1 path (static, no churn)
state0 = base.init_state(jax.random.PRNGKey(0))
state0, _ = jax.jit(base.train_step)(state0, data.batch(0))
probe = data.batch(0, probe=True)

def run2(tr, st):
    cons = jax.jit(tr.consensus_step)
    st = jax.tree_util.tree_map(lambda x: x, st)
    st, _ = cons(st, probe)
    st, m = cons(st, probe)
    return st, m

def flat(st):
    return ([np.asarray(x) for x in jax.tree_util.tree_leaves(st.params)]
            + [np.asarray(st.lam), np.asarray(st.theta_bar_prev),
               np.asarray(st.penalty.eta)])

# --- static == PR 1 fused round, bit for bit ----------------------------
# On complete the churn offset superset EQUALS the graph offsets, so the
# two programs stack identical wires and the all-ones traced mask must
# reproduce the ungated kernel exactly. (A ring superset adds offsets,
# which legitimately re-pairs fma rounding — covered by the 1e-5 dynamic
# check below instead.)
base_c = make(TopologyConfig(), topology="complete")
st0c = base_c.init_state(jax.random.PRNGKey(0))
st0c, _ = jax.jit(base_c.train_step)(st0c, data.batch(0))
st_a, _ = run2(base_c, st0c)
st_b, _ = run2(make(TopologyConfig(scheduler="static", churn=True),
                    topology="complete"), st0c)
out["static_bit_identical"] = all(
    np.array_equal(a, b) for a, b in zip(flat(st_a), flat(st_b)))

# --- mid-run node drop: no recompilation of the fused step --------------
tr = make(TopologyConfig(scheduler="budget", churn=True))
st = tr.init_state(jax.random.PRNGKey(1))
train = jax.jit(tr.train_step)
cons = jax.jit(tr.consensus_step)
for step in range(4):
    st, _ = train(st, data.batch(step))
    st, m = cons(st, probe)
pre = (train._cache_size(), cons._cache_size())
st = tr.apply_churn(st, 2)
for step in range(4, 8):
    st, _ = train(st, data.batch(step))
    st, m = cons(st, probe)
out["cache_grew"] = [train._cache_size() - pre[0],
                     cons._cache_size() - pre[1]]
out["r_max_after_drop"] = float(m["r_max"])
out["active_after_drop"] = float(m["active_edges"])
out["alive"] = np.asarray(st.topo.node_alive).tolist()

# --- dynamic fused == dynamic unfused reference -------------------------
tru = make(TopologyConfig(scheduler="round_robin", churn=True), fused=False)
trf = make(TopologyConfig(scheduler="round_robin", churn=True), fused=True)
stf, mf = run2(trf, state0)
stu, mu = run2(tru, state0)
out["dyn_fused_vs_ref_err"] = max(
    float(np.max(np.abs(a - b))) for a, b in zip(flat(stf), flat(stu)))
out["dyn_metric_err"] = max(
    abs(float(mf[k]) - float(mu[k])) / (abs(float(mu[k])) + 1.0)
    for k in mf)
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def engine_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _ENGINE], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_static_scheduler_bit_identical_to_fused_round(engine_results):
    assert engine_results["static_bit_identical"] is True


def test_node_drop_without_recompile(engine_results):
    assert engine_results["cache_grew"] == [0, 0], engine_results
    assert engine_results["alive"] == [True, True, False, True]
    assert np.isfinite(engine_results["r_max_after_drop"])
    assert 0.0 < engine_results["active_after_drop"] < 1.0


def test_dynamic_fused_matches_reference(engine_results):
    assert engine_results["dyn_fused_vs_ref_err"] < 1e-5, engine_results
    assert engine_results["dyn_metric_err"] < 1e-5, engine_results
