"""End-to-end launcher tests: train -> checkpoint -> crash -> resume,
straggler handling, and elastic node-drop (subprocess: needs 8 devices)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, timeout=1200):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


_RESUME = r"""
import os, json, shutil
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
ck = "/tmp/repro_test_resume"
shutil.rmtree(ck, ignore_errors=True)
from repro.launch.train import main
# phase 1: 8 steps, checkpoint every 4
main(["--arch", "qwen3-4b", "--reduced", "--steps", "8", "--ckpt-dir", ck,
      "--ckpt-every", "4", "--scheme", "nap", "--local-steps", "4"])
from repro.checkpoint import latest_steps
steps_after_1 = latest_steps(ck)
# phase 2 simulates a restart: same command, more steps -> resumes from 8
main(["--arch", "qwen3-4b", "--reduced", "--steps", "12", "--ckpt-dir", ck,
      "--ckpt-every", "4", "--scheme", "nap", "--local-steps", "4"])
steps_after_2 = latest_steps(ck)
print("RESULT " + json.dumps({"p1": steps_after_1, "p2": steps_after_2}))
"""


def test_train_checkpoint_resume():
    out = _run(_RESUME)
    assert 8 in out["p1"], out
    assert max(out["p2"]) == 12, out


_ELASTIC = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.core.graph import build_graph
from repro.core.penalty import PenaltyConfig, init_penalty_state
from repro.runtime import ElasticController, StragglerMonitor

# straggler detection drives the elastic drop
mon = StragglerMonitor(4, threshold=2.0, patience=2)
g = build_graph("ring", 4)
pen = init_penalty_state(PenaltyConfig(scheme="nap"), 4)
ctl = ElasticController(g)
victims = []
for step in range(6):
    durations = np.array([1.0, 1.0, 1.0, 1.0 if step < 2 else 9.0])
    slow = mon.observe(durations)
    for v in slow:
        if ctl.graph.num_nodes > 2 and not victims:
            g2, pen = ctl.drop(v, pen, step)
            victims.append(v)
print("RESULT " + json.dumps({
    "victims": victims,
    "nodes": ctl.graph.num_nodes,
    "connected": ctl.graph.is_connected(),
    "pen_shape": list(np.asarray(pen.eta).shape),
}))
"""


def test_straggler_to_elastic_pipeline():
    out = _run(_ELASTIC, timeout=600)
    assert out["victims"] == [3]
    assert out["nodes"] == 3 and out["connected"]
    assert out["pen_shape"] == [3, 3]
