"""Round-pipeline bit-identity: pipelined == sequential on every axis.

The latency-hiding pipeline (``ConsensusConfig.pipeline_offsets``) is a pure
REORDERING of the round: offset k+1's collective-permute is issued while
offset k decodes/probes/fuses, but every value consumed is unchanged — so
any pipeline depth must be BIT-identical (exact float equality, not
tolerance) to the sequential loop on params, duals, bar, penalty state,
ledger and metrics.

Covering matrix (one subprocess, shared model/mesh): every penalty scheme,
every wire codec {native, int8, fp8_e4m3}, both layouts {replicated,
sharded}, every edge scheduler {static, budget-gated, stale/async} and both
round paths (sync ``consensus_step``, async ``consensus_step_async`` with
partial arrivals holding ledger rows) appear in at least one case, with the
interesting interactions paired up — budget gating exercises the
dead-offset skip (``needs == 0`` holds the in-flight row unissued), churn
enables the kick path with pending zero-kicks against early-issued
permutes, async arrival gaps exercise held-vs-landed merge rows. The full
cross product would be ~84 trainer pairs x ~40-270 s each — cost-prohibited
for tier 1; the matrix keeps every axis value and the risky pairs.

Runs on a 4-pod mesh (ring offsets [1, 3]) so depth > 1 is non-trivial, and
sweeps intermediate bounded depths (2) as well as full depth (>= deg).
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), os.pardir)

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_reduced_config
from repro.core.penalty import PenaltyConfig
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.optim import ConsensusConfig, ConsensusTrainer
from repro.optim.adamw import AdamWConfig
from repro.async_exec.ledger import AsyncConfig
from repro.topology import TopologyConfig

mesh = make_mesh((4, 2, 1), ("pod", "data", "model"))
cfg = get_reduced_config("qwen3-4b")
model = build_model(cfg)
data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  batch_per_node=2, num_nodes=4))
probe = data.batch(0, probe=True)

def make(pipe, scheme, codec, sharded, topo, async_cfg):
    return ConsensusTrainer(
        model, mesh, adamw=AdamWConfig(lr=1e-2),
        consensus=ConsensusConfig(
            penalty=PenaltyConfig(scheme=scheme, eta0=0.1),
            topology="ring", local_steps=1, wire_codec=codec,
            shard_consensus=sharded, dyn_topology=topo,
            async_exec=async_cfg, pipeline_offsets=pipe))

# one shared local step diverges the node replicas; independent of pipe
base = make(1, "fixed", "native", False, TopologyConfig(), None)
st0 = base.init_state(jax.random.PRNGKey(0))
st0, _ = jax.jit(base.train_step)(st0, data.batch(0))
assert len(base.offsets) >= 2, base.offsets      # depth > 1 must be real

def leaves(tr, st):
    out = [np.asarray(x, np.float32)
           for x in jax.tree_util.tree_leaves(st.params)]
    out += [np.asarray(x) for x in jax.tree_util.tree_leaves(
        tr.layout.unpack(st.lam))]
    out += [np.asarray(x) for x in jax.tree_util.tree_leaves(
        tr.layout.unpack(st.theta_bar_prev))]
    out.append(np.asarray(st.penalty.eta))
    if st.ledger is not None:
        # the pipelined sync path persists its in-flight rows in the
        # ledger; sequential-vs-pipelined ledgers may differ (that IS the
        # double buffer), so only the async path — where both maintain
        # it — pins ledger bytes
        if tr.async_cfg is not None:
            out.append(np.asarray(st.ledger.wires))
            out.append(np.asarray(st.ledger.w_prev))
    return out

# round-2 arrival schedule with gaps: nodes 1 and 3 never land on offset 0,
# offset 1 lands everywhere — exercises held ledger rows under pipelining
def arrivals(tr, r):
    deg, j = len(tr.offsets), tr.num_nodes
    if r == 0:
        return jnp.ones((deg, j), bool)
    a = np.ones((deg, j), bool)
    a[0, 1] = a[0, 3] = False
    return jnp.asarray(a)

def run(tr, rounds=2):
    st = tr.init_state(jax.random.PRNGKey(0))
    st = st._replace(params=st0.params, opt=st0.opt, step=st0.step)
    if tr.async_cfg is not None:
        cons = jax.jit(tr.consensus_step_async)
        for r in range(rounds):
            st, m = cons(st, probe, arrivals(tr, r))
    else:
        cons = jax.jit(tr.consensus_step)
        for r in range(rounds):
            st, m = cons(st, probe)
    return st, {k: float(v) for k, v in m.items()}

STATIC = TopologyConfig()
# gate_tol big enough that edges actually gate OFF within two rounds ->
# the dead-offset skip holds in-flight rows that were never issued
BUDGET = TopologyConfig(scheduler="budget", gate_tol=1e2,
                        skip_dead_offsets=True)
BUDGET_KICK = TopologyConfig(scheduler="budget", gate_tol=1e2,
                             skip_dead_offsets=True, churn=True)
STALE = TopologyConfig(scheduler="stale")
ASYNC = AsyncConfig(max_staleness=1)

# scheme, codec, sharded, topo, async, depths-to-pin (vs depth 1)
CASES = {
    "fixed_native_repl_static":   ("fixed", "native", False, STATIC, None,
                                   (2, 4)),
    "vp_int8_repl_static":        ("vp", "int8", False, STATIC, None, (4,)),
    "ap_fp8_repl_static":         ("ap", "fp8_e4m3", False, STATIC, None,
                                   (4,)),
    "nap_fp8_repl_budget_kick":   ("nap", "fp8_e4m3", False, BUDGET_KICK,
                                   None, (4,)),
    "vp_nap_int8_repl_budget":    ("vp_nap", "int8", False, BUDGET, None,
                                   (2,)),
    "vp_ap_native_repl_stale":    ("vp_ap", "native", False, STALE, ASYNC,
                                   (4,)),
    "nap_int8_shard_static":      ("nap", "int8", True, STATIC, None, (4,)),
    "vp_nap_fp8_shard_stale":     ("vp_nap", "fp8_e4m3", True, STALE,
                                   ASYNC, (2,)),
}

out = {}
for name, (scheme, codec, sharded, topo, acfg, depths) in CASES.items():
    ref_tr = make(1, scheme, codec, sharded, topo, acfg)
    ref_st, ref_m = run(ref_tr)
    ref_lv = leaves(ref_tr, ref_st)
    for depth in depths:
        tr = make(depth, scheme, codec, sharded, topo, acfg)
        st, m = run(tr)
        lv = leaves(tr, st)
        err = max((float(np.max(np.abs(a - b))) if a.size else 0.0)
                  for a, b in zip(ref_lv, lv))
        merr = max(abs(ref_m[k] - m[k]) for k in ref_m)
        out[f"{name}_d{depth}"] = {"max_err": err, "metric_err": merr,
                                   "n_buffers": len(lv)}
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def pipeline_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=3000)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_matrix_covers_every_axis_value():
    """Vacuity guard on the covering matrix itself."""
    import re
    cases = re.findall(r'"(\w+)":\s+\("(\w+)", "(\w+)", (\w+),',
                       _SCRIPT)
    schemes = {c[1] for c in cases}
    codecs = {c[2] for c in cases}
    sharded = {c[3] for c in cases}
    assert schemes == {"fixed", "vp", "ap", "nap", "vp_ap", "vp_nap"}
    assert codecs == {"native", "int8", "fp8_e4m3"}
    assert sharded == {"False", "True"}
    for sched in ("STATIC", "BUDGET", "STALE", "ASYNC", "BUDGET_KICK"):
        assert f" {sched}," in _SCRIPT or f"{sched})" in _SCRIPT


def test_pipelined_bit_identical_to_sequential(pipeline_results):
    """EXACT equality at every depth, every case — params, duals, bar,
    penalty state, (async) ledger bytes, and round metrics."""
    assert len(pipeline_results) >= 9, sorted(pipeline_results)
    bad = {k: v for k, v in pipeline_results.items()
           if v["max_err"] != 0.0 or v["metric_err"] != 0.0}
    assert not bad, bad


def test_async_cases_pin_ledger_buffers(pipeline_results):
    """The async cases' comparisons must include the ledger arrays (wires
    + w_prev) on top of params/lam/bar/eta — catches a pipeline that gets
    the outputs right but corrupts the double buffer it hands the next
    round."""
    sync = pipeline_results["fixed_native_repl_static_d4"]["n_buffers"]
    for k in ("vp_ap_native_repl_stale_d4", "vp_nap_fp8_shard_stale_d2"):
        assert pipeline_results[k]["n_buffers"] == sync + 2, \
            (k, pipeline_results[k])
