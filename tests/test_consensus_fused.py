"""Fused flat-buffer round == unfused reference, on the 8-device debug mesh.

Property over the full scheme/compression domain: for every penalty scheme
(fixed, vp, ap, nap, vp_ap, vp_nap) x compression {none, int8}, two
consensus rounds through the fused Pallas engine must match the blockwise
jnp reference path to 1e-5 (params, duals, neighbor means, residual/penalty
metrics). Also pins the engine's communication contract: exactly ONE
collective-permute per graph offset and ONE Pallas call per round in the
compiled consensus_step.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import re
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_reduced_config
from repro.core.penalty import SCHEMES, PenaltyConfig
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.optim import ConsensusConfig, ConsensusTrainer
from repro.optim.adamw import AdamWConfig

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_reduced_config("qwen3-4b")
model = build_model(cfg)
data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  batch_per_node=2, num_nodes=2))

def make(scheme, compression, fused):
    return ConsensusTrainer(
        model, mesh, adamw=AdamWConfig(lr=1e-2),
        consensus=ConsensusConfig(
            penalty=PenaltyConfig(scheme=scheme, eta0=0.1),
            topology="ring", local_steps=1, compression=compression,
            use_fused_kernel=fused))

# one shared local step to diverge the node replicas; train_step is
# independent of the fused flag, so both paths start from the same state
base = make("fixed", "none", True)
state0 = base.init_state(jax.random.PRNGKey(0))
state0, _ = jax.jit(base.train_step)(state0, data.batch(0))

def leaves_of(state):
    return ([np.asarray(x, np.float32)
             for x in jax.tree_util.tree_leaves(state.params)]
            + [np.asarray(state.lam), np.asarray(state.theta_bar_prev),
               np.asarray(state.penalty.eta)])

out = {"cases": {}}
probe = data.batch(0, probe=True)
for scheme in SCHEMES:
    for compression in ("none", "int8"):
        results = []
        for fused in (True, False):
            tr = make(scheme, compression, fused)
            st = jax.tree_util.tree_map(lambda x: x, state0)  # fresh copy
            st = st._replace(penalty=tr.init_state(
                jax.random.PRNGKey(1)).penalty)
            cons = jax.jit(tr.consensus_step)
            st, m1 = cons(st, probe)
            st, m2 = cons(st, probe)
            results.append((leaves_of(st),
                            {k: float(v) for k, v in m2.items()}))
        (lf, mf), (lu, mu) = results
        max_err = max(float(np.max(np.abs(a - b)))
                      for a, b in zip(lf, lu))
        met_err = max(abs(mf[k] - mu[k]) / (abs(mu[k]) + 1.0) for k in mf)
        out["cases"][f"{scheme}_{compression}"] = {
            "max_err": max_err, "metric_rel_err": met_err}

# --- communication contract: permutes per offset, pallas calls per round --
tr = make("nap", "int8", True)
st = tr.init_state(jax.random.PRNGKey(2))
jaxpr = jax.make_jaxpr(tr.consensus_step)(st, probe)
out["pallas_calls"] = str(jaxpr).count("pallas_call")
compiled = jax.jit(tr.consensus_step).lower(st, probe).compile()
hlo = compiled.as_text()
coll_re = re.compile(r"(?<!%)\bcollective-permute(?:-start)?(?:\.\d+)?\(")
n_perm = sum(1 for line in hlo.splitlines()
             if "=" in line and coll_re.search(line.split("=", 1)[1]))
out["collective_permutes"] = n_perm
out["num_offsets"] = len(tr.offsets)
out["num_leaves"] = tr.layout.num_leaves
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def fused_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_all_schemes_and_compressions_match(fused_results):
    cases = fused_results["cases"]
    assert len(cases) == 12, sorted(cases)
    bad = {k: v for k, v in cases.items()
           if v["max_err"] > 1e-5 or v["metric_rel_err"] > 1e-5}
    assert not bad, bad


def test_one_pallas_call_per_round(fused_results):
    assert fused_results["pallas_calls"] == 1, fused_results


def test_one_permute_per_graph_offset(fused_results):
    """Collective traffic scales with graph degree, NOT with leaf count."""
    assert fused_results["num_leaves"] > 1          # guard: test is vacuous
    assert fused_results["collective_permutes"] == \
        fused_results["num_offsets"], fused_results
