"""Fused flat-buffer round == unfused reference, on the 8-device debug mesh.

Property over the full scheme/compression domain: for every penalty scheme
(fixed, vp, ap, nap, vp_ap, vp_nap) x compression {none, int8}, two
consensus rounds through the fused Pallas engine must match the blockwise
jnp reference path to 1e-5 (params, duals, neighbor means, residual/penalty
metrics) — and the SHARDED engine (`shard_consensus=True`: flat state
split `P('pod', ('data', 'model'))`, per-slab kernel runs, psum'd
residuals) must match the unsharded round on the same domain. Also pins
the engine's communication contract: exactly ONE collective-permute per
graph offset and ONE Pallas call per round in the compiled consensus_step,
on both paths — the sharded permutes moving per-shard wire slabs — plus
the per-device HBM contract: each device holds 1/(in-pod size) of the flat
lam buffer.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import re
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_reduced_config
from repro.core.penalty import SCHEMES, PenaltyConfig
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.optim import ConsensusConfig, ConsensusTrainer
from repro.optim.adamw import AdamWConfig

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_reduced_config("qwen3-4b")
model = build_model(cfg)
data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  batch_per_node=2, num_nodes=2))

def make(scheme, compression, fused, sharded=False):
    return ConsensusTrainer(
        model, mesh, adamw=AdamWConfig(lr=1e-2),
        consensus=ConsensusConfig(
            penalty=PenaltyConfig(scheme=scheme, eta0=0.1),
            topology="ring", local_steps=1, compression=compression,
            use_fused_kernel=fused, shard_consensus=sharded))

# one shared local step to diverge the node replicas; train_step is
# independent of the fused flag, so both paths start from the same state
base = make("fixed", "none", True)
state0 = base.init_state(jax.random.PRNGKey(0))
state0, _ = jax.jit(base.train_step)(state0, data.batch(0))

def leaves_of(state):
    return ([np.asarray(x, np.float32)
             for x in jax.tree_util.tree_leaves(state.params)]
            + [np.asarray(state.lam), np.asarray(state.theta_bar_prev),
               np.asarray(state.penalty.eta)])

def leaves_unpacked(tr, state):
    # layout-independent view: the sharded layout pads the flat TOTAL to
    # the shard grid, so raw lam/bar shapes differ — compare through the
    # per-leaf views (the padding region is pinned zero elsewhere)
    return ([np.asarray(x, np.float32)
             for x in jax.tree_util.tree_leaves(state.params)]
            + [np.asarray(x) for x in jax.tree_util.tree_leaves(
                tr.layout.unpack(state.lam))]
            + [np.asarray(x) for x in jax.tree_util.tree_leaves(
                tr.layout.unpack(state.theta_bar_prev))]
            + [np.asarray(state.penalty.eta)])

def run_two_rounds(tr):
    st = jax.tree_util.tree_map(lambda x: x, state0)      # fresh copy
    flat = (tr.num_nodes, tr.layout.total)
    st = st._replace(
        lam=jnp.zeros(flat, jnp.float32),
        theta_bar_prev=jnp.zeros(flat, jnp.float32),
        penalty=tr.init_state(jax.random.PRNGKey(1)).penalty)
    cons = jax.jit(tr.consensus_step)
    st, m1 = cons(st, probe)
    st, m2 = cons(st, probe)
    return st, {k: float(v) for k, v in m2.items()}

out = {"cases": {}, "sharded_cases": {}}
probe = data.batch(0, probe=True)
for scheme in SCHEMES:
    for compression in ("none", "int8"):
        results = []
        for fused in (True, False):
            tr = make(scheme, compression, fused)
            st, m2 = run_two_rounds(tr)
            results.append((leaves_of(st), m2, leaves_unpacked(tr, st)))
        (lf, mf, luf), (lu, mu, luu) = results
        max_err = max(float(np.max(np.abs(a - b)))
                      for a, b in zip(lf, lu))
        met_err = max(abs(mf[k] - mu[k]) / (abs(mu[k]) + 1.0) for k in mf)
        out["cases"][f"{scheme}_{compression}"] = {
            "max_err": max_err, "metric_rel_err": met_err}
        # sharded engine vs the unsharded fused round, same two rounds:
        # elementwise math is identical per slab; only the psum'd residual
        # metrics may differ by f32 reduction order
        trs = make(scheme, compression, True, sharded=True)
        sts, ms = run_two_rounds(trs)
        ls = leaves_unpacked(trs, sts)
        smax_err = max(float(np.max(np.abs(a - b)))
                       for a, b in zip(ls, luf))
        smet_err = max(abs(ms[k] - mf[k]) / (abs(mf[k]) + 1.0) for k in ms)
        out["sharded_cases"][f"{scheme}_{compression}"] = {
            "max_err": smax_err, "metric_rel_err": smet_err}

# --- communication contract: permutes per offset, pallas calls per round --
tr = make("nap", "int8", True)
st = tr.init_state(jax.random.PRNGKey(2))
jaxpr = jax.make_jaxpr(tr.consensus_step)(st, probe)
out["pallas_calls"] = str(jaxpr).count("pallas_call")
compiled = jax.jit(tr.consensus_step).lower(st, probe).compile()
hlo = compiled.as_text()
coll_re = re.compile(r"(?<!%)\bcollective-permute(?:-start)?(?:\.\d+)?\(")
n_perm = sum(1 for line in hlo.splitlines()
             if "=" in line and coll_re.search(line.split("=", 1)[1]))
out["collective_permutes"] = n_perm
out["num_offsets"] = len(tr.offsets)
out["num_leaves"] = tr.layout.num_leaves

# --- sharded contract: wire-slab permutes, pallas calls, per-device HBM --
trs = make("nap", "int8", True, sharded=True)
sts = trs.init_state(jax.random.PRNGKey(2))
sts = sts._replace(
    lam=jnp.zeros((trs.num_nodes, trs.layout.total), jnp.float32),
    theta_bar_prev=jnp.zeros((trs.num_nodes, trs.layout.total),
                             jnp.float32))
out["sharded_pallas_calls"] = str(
    jax.make_jaxpr(trs.consensus_step)(sts, probe)).count("pallas_call")
compiled_s = jax.jit(trs.consensus_step).lower(sts, probe).compile()
hlo_s = compiled_s.as_text()
# a DCN wire permute moves one per-device slab of the sharded wire
# (1 node row x one shard's wire width, int8); in-pod resharding
# collectives around the probes are smaller — count only wire-sized ones
slab_elems = trs.slayout.wire_width("int8")
shape_re = re.compile(r"s8\[([0-9,]+)\]")
n_wire_perm = 0
for line in hlo_s.splitlines():
    if "=" not in line or not coll_re.search(line.split("=", 1)[1]):
        continue
    m = shape_re.search(line.split("=", 1)[1])
    elems = 1
    if m:
        for d in m.group(1).split(","):
            elems *= int(d)
    if elems >= slab_elems:
        n_wire_perm += 1
out["sharded_wire_permutes"] = n_wire_perm
out["sharded_n_shards"] = trs.n_shards
# probe-path resharding contract: decoding once per offset and pinning
# the probe params in-pod replicated (_probe_params) costs ONE
# payload-sized all-gather per offset — the regression this guards
# against re-sharded per LEAF inside the probe's unpack (~num_leaves
# payload-scale collectives per offset). Filter by slab size so the
# model's own (small, activation-scale) gathers don't count.
ag_re = re.compile(r"(?<!%)\ball-gather(?:-start)?(?:\.\d+)?\(")
shape_any_re = re.compile(r"\b[a-z0-9]+\[([0-9,]*)\]")
payload_slab = trs.layout.total // trs.n_shards
n_big_ag = 0
for line in hlo_s.splitlines():
    if "=" not in line:
        continue
    lhs = line.split("=", 1)[1]
    m = ag_re.search(lhs)
    if not m:
        continue
    elems = 0
    for dims in shape_any_re.findall(lhs[:lhs.find("all-gather")]):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems = max(elems, n)
    if elems >= payload_slab:
        n_big_ag += 1
out["sharded_big_all_gathers"] = n_big_ag
# per-device consensus-state HBM: each device holds 1/n_shards of its
# pod's flat lam row (the ISSUE acceptance shrink, measured for real)
sts2, _ = jax.jit(trs.consensus_step)(sts, probe)
shard_elems = {int(s.data.size) for s in sts2.lam.addressable_shards}
out["sharded_lam_shard_elems"] = sorted(shard_elems)
out["sharded_lam_expected_elems"] = trs.layout.total // trs.n_shards
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def fused_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_all_schemes_and_compressions_match(fused_results):
    cases = fused_results["cases"]
    assert len(cases) == 12, sorted(cases)
    bad = {k: v for k, v in cases.items()
           if v["max_err"] > 1e-5 or v["metric_rel_err"] > 1e-5}
    assert not bad, bad


def test_one_pallas_call_per_round(fused_results):
    assert fused_results["pallas_calls"] == 1, fused_results


def test_one_permute_per_graph_offset(fused_results):
    """Collective traffic scales with graph degree, NOT with leaf count."""
    assert fused_results["num_leaves"] > 1          # guard: test is vacuous
    assert fused_results["collective_permutes"] == \
        fused_results["num_offsets"], fused_results


def test_sharded_matches_unsharded_all_schemes(fused_results):
    """Satellite pin: the sharded engine == the unsharded fused round for
    all 6 schemes x {none, int8} on the static topology.

    The per-slab kernel math is elementwise-identical (same inputs, same
    op order per element), so params/duals/bar match to f32 exactness;
    only the residual METRICS go through a psum whose f32 summation order
    differs from the single-row reduction — hence the looser metric bound.
    """
    cases = fused_results["sharded_cases"]
    assert len(cases) == 12, sorted(cases)
    bad = {k: v for k, v in cases.items()
           if v["max_err"] > 1e-5 or v["metric_rel_err"] > 5e-4}
    assert not bad, bad


def test_sharded_one_wire_permute_per_offset(fused_results):
    """The sharded exchange still moves ONE wire message per graph offset
    — a per-shard slab (payload + in-band scale tail) over the pod axis."""
    assert fused_results["sharded_pallas_calls"] == 1, fused_results
    assert fused_results["sharded_wire_permutes"] == \
        fused_results["num_offsets"], fused_results


def test_sharded_probe_gathers_once_per_offset(fused_results):
    """Satellite pin: the sharded probe path decodes/unpacks ONCE per
    offset with the payload pinned in-pod replicated, so payload-sized
    all-gathers stay O(offsets) — never O(num_leaves) per-leaf reshards
    (the bug this PR fixed). Budget: the probe's payload gather plus at
    most one flat-state gather per offset, +1 for round-level slack."""
    budget = 2 * fused_results["num_offsets"] + 1
    assert fused_results["sharded_big_all_gathers"] <= budget, fused_results
    # guard against vacuity: the leaf count must dwarf the budget, or the
    # per-leaf regression would pass the pin
    assert fused_results["num_leaves"] > budget, fused_results


def test_sharded_lam_is_slab_resident(fused_results):
    """Acceptance pin: per-device flat-state HBM shrinks by the in-pod
    axis size — each device materializes exactly total/n_shards elements
    of its pod's lam row after a sharded round."""
    assert fused_results["sharded_n_shards"] == 4   # 2x2 in-pod grid
    assert fused_results["sharded_lam_shard_elems"] == \
        [fused_results["sharded_lam_expected_elems"]], fused_results
