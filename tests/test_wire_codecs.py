"""Engine pins for the fp8 wire codecs (repro.wire): fused == reference.

Two subprocesses on the 8-fake-device mesh (J=4 pods, 2-way in-pod
sharding) sweep fused-vs-reference and sharded-vs-unsharded rounds with
the fp8 codecs across the gating modes (split in two so each stays well
inside the CI subprocess timeout on 2-core runners):

  * ``static`` (subprocess A) — all 6 penalty schemes x both fp8 codecs x
    {reference, fused, fused+sharded}, one sync round each at f32
    round-off (identical wire bytes in), plus the roofline wire-bytes
    contract;
  * ``budget`` (subprocess B) — forced-exhaustion budget gating on the
    complete graph (zero initial budget + huge gate_tol gates every chord
    after round 1, round 2 absorbs the parked kicks) for the
    budget-capable schemes (nap, vp_nap — the budget scheduler REJECTS
    non-budget penalties by construction, so the other four schemes
    cannot run this mode), e4m3 on all three paths + an e5m2 spot check;
  * ``stale`` (subprocess B) — bounded-staleness async rounds (complete
    graph, sender 0 lands only at tick 0 => its edges age 0,1,2 and gate
    with an in-round ledger zero-kick at tick 2) for all 6 schemes with
    fp8_e4m3 {ref, fused} + sharded and e5m2 spot checks. The two fp8
    codecs share every line of codec/kernel code except the dtype
    constant and its finite-range clamp — both already pinned bit-exact
    by the roundtrip property harness in test_flatten_sharded.py — so the
    e5m2 spot checks carry the same evidence as a full sweep. Revival
    after gating is wire-format-independent executor logic, pinned at
    int8/native precision in test_async_exec.py.

Documented fp8 tolerance: both paths decode the SAME wire bytes each
round, so single-round fused-vs-ref differences are f32 round-off; over
multiple rounds the paths may drift by bf16 param-storage ulps which the
next encode amplifies to one fp8 LSB of the per-block absmax scale
(e4m3: absmax * 2^-4) — hence rtol 1e-2 with an atol of one wire LSB,
mirroring the int8 staleness pins in test_async_exec.py. Sharded vs
unsharded stays at f32 exactness (1e-5): per-block scales are slab-local,
so the sharded engine consumes byte-identical wire.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PREAMBLE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.async_exec import AsyncConfig
from repro.configs import get_reduced_config
from repro.core.penalty import SCHEMES, PenaltyConfig, init_penalty_state
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.optim import ConsensusConfig, ConsensusTrainer
from repro.optim.adamw import AdamWConfig
from repro.topology import TopologyConfig

mesh = make_mesh((4, 2, 1), ("pod", "data", "model"))
cfg = get_reduced_config("qwen3-4b")
model = build_model(cfg)
data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=16,
                                  batch_per_node=1, num_nodes=4))
probe = data.batch(0, probe=True)
FP8 = ("fp8_e4m3", "fp8_e5m2")
out = {}

def make(codec, scheme="nap", fused=True, sharded=False, topology="ring",
         dyn=None, async_cfg=None, penalty=None):
    return ConsensusTrainer(
        model, mesh, adamw=AdamWConfig(lr=1e-2),
        consensus=ConsensusConfig(
            penalty=penalty or PenaltyConfig(scheme=scheme, eta0=0.1),
            topology=topology, local_steps=1, wire_codec=codec,
            use_fused_kernel=fused, shard_consensus=sharded,
            dyn_topology=dyn or TopologyConfig(), async_exec=async_cfg))

base = make("fp8_e4m3")
state0 = base.init_state(jax.random.PRNGKey(0))
state0, _ = jax.jit(base.train_step)(state0, data.batch(0))

def leaves(tr, st):
    # layout-independent view (params + per-leaf lam/bar + penalties)
    return ([np.asarray(x, np.float32)
             for x in jax.tree_util.tree_leaves(st.params)]
            + [np.asarray(x) for x in jax.tree_util.tree_leaves(
                tr.layout.unpack(st.lam))]
            + [np.asarray(x) for x in jax.tree_util.tree_leaves(
                tr.layout.unpack(st.theta_bar_prev))]
            + [np.asarray(st.penalty.eta)])

def sync_rounds(tr, rounds=2):
    st = jax.tree_util.tree_map(lambda x: x, state0)
    flat = (tr.num_nodes, tr.layout.total)
    st = st._replace(
        lam=jnp.zeros(flat, jnp.float32),
        theta_bar_prev=jnp.zeros(flat, jnp.float32),
        penalty=init_penalty_state(tr.ccfg.penalty, tr.num_nodes),
        topo=tr.topo_rt.init_state(),
        ledger=None)
    cons = jax.jit(tr.consensus_step)
    m = {}
    for _ in range(rounds):
        st, m = cons(st, probe)
    return leaves(tr, st), {k: float(v) for k, v in m.items()}, st

def errs(a, b):
    lerr = max(float(np.max(np.abs(x - y))) for x, y in zip(a[0], b[0]))
    merr = max(abs(a[1][k] - b[1][k]) / (abs(b[1][k]) + 1.0) for k in b[1])
    return {"max_err": lerr, "metric_rel_err": merr}

def close(a, b, atol):
    return bool(all(np.allclose(x, y, rtol=1e-2, atol=atol)
                    for x, y in zip(a[0], b[0])))

# one wire LSB of the per-block absmax scale at the observed param range
ATOL = {"fp8_e4m3": 3e-2, "fp8_e5m2": 6e-2}
"""

_STATIC = _PREAMBLE + r"""
# --- static: 6 schemes x 2 fp8 codecs x {ref, fused, fused+sharded} ------
# ONE round: fused and reference consume byte-identical wire, so the RAW
# f32 flat state (lam, theta_bar_prev, eta) pins at f32 round-off; the
# bf16-STORED params may legitimately differ by one storage ulp when a
# ~1e-8 f32 difference lands on a bf16 rounding boundary. (Comparing the
# flat state through a bf16-casting view would quantize that same 1e-8
# into a full bf16 ulp — hence the raw views here. Multi-round drift is
# the wire-precision regime the budget/stale pins cover.)
T0 = base.layout.total          # common width: sharded layouts pad MORE

def fviews(st):                 # raw f32 flat state, common-width slice
    return [np.asarray(st.lam)[:, :T0],
            np.asarray(st.theta_bar_prev)[:, :T0],
            np.asarray(st.penalty.eta)]

def pviews(st):
    return [np.asarray(x, np.float32)
            for x in jax.tree_util.tree_leaves(st.params)]

def static_errs(a, b):
    return {
        "flat_err": max(float(np.max(np.abs(x - y)))
                        for x, y in zip(fviews(a[2]), fviews(b[2]))),
        "param_err": max(float(np.max(np.abs(x - y)))
                         for x, y in zip(pviews(a[2]), pviews(b[2]))),
        "metric_rel_err": errs(a, b)["metric_rel_err"]}

out["static"] = {}
for scheme in SCHEMES:
    for codec in FP8:
        ref = sync_rounds(make(codec, scheme, fused=False), rounds=1)
        fus = sync_rounds(make(codec, scheme), rounds=1)
        shd = sync_rounds(make(codec, scheme, sharded=True), rounds=1)
        out["static"][f"{scheme}_{codec}"] = {
            "fused_vs_ref": static_errs(fus, ref),
            "sharded_vs_fused": static_errs(shd, fus)}

# --- wire contract: fp8 roofline bytes = 1 B/param + 4 B/block -----------
from repro.launch.dryrun import fused_round_roofline
out["wire"] = {}
for codec in FP8:
    tr = make(codec)
    rl = fused_round_roofline(model, mesh, compression=codec)
    out["wire"][codec] = {
        "roofline_row_bytes": rl["wire_bytes_per_round"]
        // max(rl["active_offsets"], 1),
        "expected_row_bytes": tr.layout.total + 4 * tr.layout.num_blocks,
        "trainer_row_bytes": tr.codec.wire_bytes(),
        "native_row_bytes": fused_round_roofline(
            model, mesh, compression="native")["wire_bytes_per_round"]
        // max(rl["active_offsets"], 1),
    }
print("RESULT " + json.dumps(out))
"""

_GATED = _PREAMBLE + r"""
# --- budget-gated: forced exhaustion on the complete graph ---------------
# (budget-capable schemes only: the scheduler validates uses_budget)
out["budget"] = {}
bdyn = TopologyConfig(scheduler="budget", gate_tol=1e9)
budget_grid = [("nap", "fp8_e4m3", True), ("vp_nap", "fp8_e4m3", True),
               ("nap", "fp8_e5m2", False)]
for scheme, codec, with_sharded in budget_grid:
    bpen = PenaltyConfig(scheme=scheme, eta0=0.1, budget_init=0.0)
    kw = dict(topology="complete", dyn=bdyn, penalty=bpen)
    ref = sync_rounds(make(codec, scheme, fused=False, **kw))
    fus = sync_rounds(make(codec, scheme, **kw))
    rec = {"fused_vs_ref": errs(fus, ref),
           "fused_vs_ref_close": close(fus, ref, ATOL[codec]),
           "gated": fus[1]["active_edges"] < 1.0}
    if with_sharded:
        shd = sync_rounds(make(codec, scheme, sharded=True, **kw))
        rec["sharded_vs_fused"] = errs(shd, fus)
    out["budget"][f"{scheme}_{codec}"] = rec

# --- stale: bounded-staleness gating + in-round ledger kick --------------
def arrivals_for(tr, tick):
    deg = len(tr.offsets)
    j = tr.num_nodes
    idx = np.arange(j)
    arr = np.zeros((deg, j), bool)
    for d, off in enumerate(tr.offsets):
        senders = (idx + off) % j
        arr[d] = (senders != 0) | (tick % 3 == 0)
    return jnp.asarray(arr)

def stale_rounds(tr, ticks=3):
    # 3 ticks: sender 0 lands at t0 only, so its edges age 0,1,2 — past
    # the bound at t2, gating + the in-round ledger zero-kick (the codec-
    # dependent halves); revival is format-independent executor logic
    st = tr.init_state(jax.random.PRNGKey(0))
    st, _ = jax.jit(tr.train_step)(st, data.batch(0))
    step = jax.jit(tr.consensus_step_async)
    m = {}
    for t in range(ticks):
        st, m = step(st, probe, arrivals_for(tr, t), None)
    return leaves(tr, st), {k: float(v) for k, v in m.items()}

out["stale"] = {}
acfg = AsyncConfig(max_staleness=1)
sdyn = TopologyConfig(scheduler="stale", max_staleness=1)
stale_grid = [(s, "fp8_e4m3", s == "nap") for s in SCHEMES] \
    + [("nap", "fp8_e5m2", True)]
for scheme, codec, with_sharded in stale_grid:
    kw = dict(topology="complete", dyn=sdyn, async_cfg=acfg)
    ref = stale_rounds(make(codec, scheme, fused=False, **kw))
    fus = stale_rounds(make(codec, scheme, **kw))
    rec = {"fused_vs_ref": errs(fus, ref),
           "fused_vs_ref_close": close(fus, ref, ATOL[codec]),
           "gating_seen": max(fus[1]["stale_edges"],
                              ref[1]["stale_edges"]) > 0}
    if with_sharded:
        shd = stale_rounds(make(codec, scheme, sharded=True, **kw))
        rec["sharded_vs_fused"] = errs(shd, fus)
    out["stale"][f"{scheme}_{codec}"] = rec
print("RESULT " + json.dumps(out))
"""


def _run(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


@pytest.fixture(scope="module")
def static_results():
    return _run(_STATIC)


@pytest.fixture(scope="module")
def gated_results():
    return _run(_GATED)


def test_static_fp8_fused_matches_reference_all_schemes(static_results):
    """All 6 schemes x both fp8 codecs: a static sync round through the
    fused engine == the jnp reference. The raw f32 flat state (lam, bar,
    eta) pins at f32 round-off — both paths decode the same fp8 wire
    bytes, so no quantization term enters the bound; the bf16-STORED
    params get one storage-ulp of slack (a ~1e-8 f32 difference on a
    bf16 rounding boundary flips the stored bit)."""
    cases = static_results["static"]
    assert len(cases) == 12, sorted(cases)
    bad = {k: v for k, v in cases.items()
           if v["fused_vs_ref"]["flat_err"] > 1e-5
           or v["fused_vs_ref"]["param_err"] > 4e-3      # one bf16 ulp
           or v["fused_vs_ref"]["metric_rel_err"] > 1e-5}
    assert not bad, bad


def test_static_fp8_sharded_matches_unsharded_all_schemes(static_results):
    """Sharded == unsharded at f32 exactness on the fp8 wire: per-block
    scales are slab-local, so the slab engine consumes byte-identical
    payloads (metrics go through the residual psum => looser bound)."""
    cases = static_results["static"]
    bad = {k: v for k, v in cases.items()
           if v["sharded_vs_fused"]["flat_err"] > 1e-5
           or v["sharded_vs_fused"]["param_err"] > 1e-5
           or v["sharded_vs_fused"]["metric_rel_err"] > 5e-4}
    assert not bad, bad


def test_fp8_roofline_wire_bytes_shrink(static_results):
    """Acceptance pin: the dryrun roofline reads fp8 wire volume from the
    codec — exactly 1 B/param + 4 B per block of per-block f32 scale, and
    strictly smaller than the native wire."""
    for codec, rec in static_results["wire"].items():
        assert rec["roofline_row_bytes"] == rec["expected_row_bytes"], rec
        assert rec["trainer_row_bytes"] == rec["expected_row_bytes"], rec
        assert rec["roofline_row_bytes"] < rec["native_row_bytes"], rec


def test_budget_gated_fp8_fused_matches_reference(gated_results):
    """Forced-exhaustion budget gating (gate + parked-kick absorption)
    through the fp8 wire: fused == reference at wire precision, sharded ==
    unsharded at f32 exactness, and gating actually fired."""
    cases = gated_results["budget"]
    assert len(cases) == 3, sorted(cases)
    for k, v in cases.items():
        assert v["gated"], (k, v)
        assert v["fused_vs_ref_close"], (k, v)
        assert v["fused_vs_ref"]["metric_rel_err"] < 1e-2, (k, v)
        if "sharded_vs_fused" in v:
            assert v["sharded_vs_fused"]["max_err"] <= 1e-5, (k, v)


def test_stale_fp8_fused_matches_reference(gated_results):
    """Bounded-staleness rounds (ledger fallback, staleness gating,
    in-round zero-kick) through the fp8 wire: fused == reference at the
    documented wire precision for all 6 schemes."""
    cases = gated_results["stale"]
    assert len(cases) == 7, sorted(cases)
    for k, v in cases.items():
        assert v["gating_seen"], (k, v)
        assert v["fused_vs_ref_close"], (k, v)
        assert v["fused_vs_ref"]["metric_rel_err"] < 1e-2, (k, v)


def test_stale_fp8_sharded_matches_unsharded(gated_results):
    """The sharded stale round (per-shard fp8 ledger rows, slab-local
    scale decode) == the unsharded fused round at f32 exactness."""
    cases = {k: v for k, v in gated_results["stale"].items()
             if "sharded_vs_fused" in v}
    assert len(cases) == 2, sorted(gated_results["stale"])
    for k, v in cases.items():
        assert v["sharded_vs_fused"]["max_err"] <= 1e-5, (k, v)
