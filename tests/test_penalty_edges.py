"""Edge-case coverage for ``repro.core.penalty`` (async-PR satellite).

Pins the corners the async executor leans on: budget exhaustion and
revival on fully-gated / just-revived edges, ``effective_eta`` under
topology gating and staleness damping, and clip behavior when the tau
probes hit their analytic extremes.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.penalty import (PenaltyConfig, budget_exhausted,
                                compute_tau, effective_eta, freeze_penalty,
                                init_penalty_state, staleness_damping,
                                update_penalty)


def _adj(j):
    return jnp.asarray(~np.eye(j, dtype=bool))


# ----------------------------------------------------- budget corners ----
def test_budget_exhausted_on_fully_gated_then_revived_edges():
    cfg = PenaltyConfig(scheme="nap", eta0=1.0, budget_init=1.0)
    st = init_penalty_state(cfg, 4)
    # spend every directed budget
    st = st._replace(cum_tau=st.budget + 0.5)
    ex = np.asarray(budget_exhausted(st))
    assert ex.all()
    # a top-up (eq. 10) on one edge revives exactly that edge
    budget = np.asarray(st.budget).copy()
    budget[1, 2] = float(st.cum_tau[1, 2]) + 1.0
    st2 = st._replace(budget=jnp.asarray(budget))
    ex2 = np.asarray(budget_exhausted(st2))
    assert not ex2[1, 2] and ex2[2, 1]          # directed semantics
    assert ex2.sum() == ex.sum() - 1


def test_budget_topup_fires_only_while_objective_moves():
    cfg = PenaltyConfig(scheme="nap", eta0=1.0, budget_init=1.0,
                        beta=1e-3, relative_beta=True)
    j = 3
    adj = _adj(j)
    st = init_penalty_state(cfg, j)
    # exhausted by a hair: one geometric top-up (alpha^1 T = 0.5) reopens
    st = st._replace(cum_tau=st.budget + 0.1,
                     f_prev=jnp.asarray([1.0, 1.0, 1.0]))
    f_move = jnp.asarray([2.0, 1.0, 1.0])               # node 0 moving
    f_nbr = jnp.broadcast_to(f_move[:, None], (j, j))
    st2 = update_penalty(cfg, st, adj=adj, f_self=f_move, f_nbr=f_nbr)
    topped = np.asarray(st2.budget) > np.asarray(st.budget)
    assert topped[0].sum() == 2                 # node 0's edges revived
    assert not topped[1:].any()                 # calm nodes stay exhausted
    assert (np.asarray(st2.n_incr)[0, 1:] == 1).all()
    # revived edges are no longer exhausted (the stale/budget gate reopens)
    assert not np.asarray(budget_exhausted(st2))[0, 1:].any()


# ---------------------------------------------------- effective eta ------
def test_effective_eta_fully_gated_and_just_revived():
    cfg = PenaltyConfig(scheme="nap", eta0=2.0)
    j = 3
    st = init_penalty_state(cfg, j)
    eta = np.full((j, j), 5.0, np.float32)      # adapted away from eta0
    st = st._replace(eta=jnp.asarray(eta))
    gated = jnp.zeros((j, j), bool)             # fully-gated topology
    assert float(jnp.abs(effective_eta(cfg, st, gated)).max()) == 0.0
    # just-revived edge re-enters at its ADAPTED eta, not eta0
    one = np.zeros((j, j), bool)
    one[0, 1] = one[1, 0] = True
    eff = np.asarray(effective_eta(cfg, st, jnp.asarray(one)))
    assert eff[0, 1] == 5.0 and eff[1, 0] == 5.0
    assert eff.sum() == 10.0


def test_effective_eta_staleness_damping():
    cfg = PenaltyConfig(scheme="nap", eta0=2.0)
    j = 3
    st = init_penalty_state(cfg, j)
    adj = _adj(j)
    age = np.zeros((j, j), np.int32)
    age[0, 1] = age[1, 0] = 4
    eff = np.asarray(effective_eta(cfg, st, adj, age=jnp.asarray(age),
                                   stale_gamma=0.5))
    assert eff[0, 1] == pytest.approx(2.0 / 3.0)    # 2 / (1 + 0.5*4)
    assert eff[0, 2] == 2.0                         # fresh edge undamped


def test_staleness_damping_properties():
    age = jnp.asarray([0, 1, 2, 10, 100], jnp.int32)
    d = np.asarray(staleness_damping(age, 0.5))
    assert d[0] == 1.0                              # fresh == exactly 1
    assert (np.diff(d) < 0).all()                   # strictly decreasing
    assert (d > 0).all()
    assert np.asarray(staleness_damping(age, 0.0)).tolist() == [1.0] * 5


# ------------------------------------------------- per-edge freezing ----
def _states_for_freeze(j=4):
    cfg = PenaltyConfig(scheme="nap", eta0=1.0)
    old = init_penalty_state(cfg, j)
    rng = np.random.default_rng(7)
    new = old._replace(
        eta=jnp.asarray(rng.uniform(1.5, 3.0, (j, j)).astype(np.float32)),
        cum_tau=jnp.asarray(rng.uniform(0, 1, (j, j)).astype(np.float32)),
        budget=jnp.asarray(rng.uniform(1, 2, (j, j)).astype(np.float32)),
        n_incr=jnp.asarray(rng.integers(0, 3, (j, j)).astype(np.int32)),
        f_prev=jnp.asarray(rng.uniform(0, 1, (j,)).astype(np.float32)),
        t=old.t + 1)
    return old, new


def test_freeze_penalty_is_per_edge_and_symmetric():
    """Regression for the ROADMAP row-freeze asymmetry: node 0 frozen,
    nodes 1..3 advancing. The old whole-ROW freeze kept eta[0, j] at the
    old value while eta[j, 0] adapted — the applied symmetrized weight
    0.5*(eta_ij + eta_ji) then disagreed with both endpoints' view of the
    edge. Per-edge freezing updates BOTH directions of an edge whenever
    either endpoint advanced; this test FAILS on the row-freeze behavior
    (eta[0, 1] would stay old)."""
    old, new = _states_for_freeze()
    adv = jnp.asarray([False, True, True, True])
    out = freeze_penalty(adv, new, old)
    eta = np.asarray(out.eta)
    # the frozen node's edges to advancing neighbors took the NEW values
    # in BOTH directions (row-freeze keeps eta[0, 1:] old -> this fails)
    np.testing.assert_array_equal(eta[0, 1:], np.asarray(new.eta)[0, 1:])
    np.testing.assert_array_equal(eta[1:, 0], np.asarray(new.eta)[1:, 0])
    # update-cadence symmetry: both directions of every edge came from the
    # same state (old or new), so cadence never desynchronizes
    took_new = eta == np.asarray(new.eta)
    np.testing.assert_array_equal(took_new, took_new.T)
    # per-node probe memory still freezes with the node
    f_prev = np.asarray(out.f_prev)
    assert f_prev[0] == np.asarray(old.f_prev)[0]
    np.testing.assert_array_equal(f_prev[1:], np.asarray(new.f_prev)[1:])


def test_freeze_penalty_both_endpoints_frozen_keeps_old():
    old, new = _states_for_freeze()
    adv = jnp.asarray([False, False, True, True])
    out = freeze_penalty(adv, new, old)
    # the frozen-frozen edge (0, 1) stays at OLD values, both directions
    assert float(out.eta[0, 1]) == float(old.eta[0, 1])
    assert float(out.eta[1, 0]) == float(old.eta[1, 0])
    assert float(out.cum_tau[0, 1]) == float(old.cum_tau[0, 1])
    assert int(out.n_incr[1, 0]) == int(old.n_incr[1, 0])
    # everyone advancing == plain new state; no one advancing == old edges
    all_new = freeze_penalty(jnp.ones(4, bool), new, old)
    np.testing.assert_array_equal(np.asarray(all_new.eta),
                                  np.asarray(new.eta))
    none_new = freeze_penalty(jnp.zeros(4, bool), new, old)
    np.testing.assert_array_equal(np.asarray(none_new.eta),
                                  np.asarray(old.eta))


# ------------------------------------------------------ clip extremes ----
def test_clip_at_tau_extremes():
    """tau in [-1/2, 1] (eq. 7/8): drive probes to both extremes and pin
    eta's clip behavior at eta_min / eta_max."""
    j = 3
    adj = _adj(j)
    # extreme probe split: node 0 thinks itself worst (kappa_self=2,
    # neighbors at 1) => tau = +1 on its edges; neighbors see tau = -1/2
    f_self = jnp.asarray([2.0, 1.0, 1.0])
    f_nbr = jnp.asarray([[0.0, 1.0, 1.0],
                         [2.0, 0.0, 2.0],
                         [2.0, 2.0, 0.0]])
    tau = np.asarray(compute_tau(adj, f_self, f_nbr))
    assert tau[0, 1] == pytest.approx(1.0)
    assert tau[1, 0] == pytest.approx(-0.5)
    # ap eta = eta0 (1 + tau) in [eta0/2, 2 eta0]; tight eta_max clips the
    # grow side, tight eta_min clips the shrink side, eta0 is NOT clipped
    # on non-edges (they are pinned to eta0 by construction)
    cfg = PenaltyConfig(scheme="ap", eta0=1.0, eta_min=0.6, eta_max=1.5)
    st = update_penalty(cfg, init_penalty_state(cfg, j), adj=adj,
                        f_self=f_self, f_nbr=f_nbr)
    eta = np.asarray(st.eta)
    assert eta[0, 1] == 1.5                     # 2.0 clipped to eta_max
    assert eta[1, 0] == 0.6                     # 0.5 clipped to eta_min
    assert eta[0, 0] == 1.0                     # diagonal pinned to eta0
    # degenerate neighborhood (all probes equal): tau = 0, eta = eta0
    flat = jnp.ones((j,))
    st2 = update_penalty(cfg, init_penalty_state(cfg, j), adj=adj,
                         f_self=flat, f_nbr=jnp.ones((j, j)))
    assert np.allclose(np.asarray(st2.eta), 1.0)
