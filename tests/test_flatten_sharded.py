"""Property-test harness for the shard-aware flat layout (repro.optim.flatten).

Randomized pytrees (odd leaf sizes, mixed bf16/f32 dtypes, empty and scalar
leaves, block sizes 128..64k) drive four pinned properties:

  * pack -> unpack round-trips exactly, with zero-filled padding;
  * ``shard(n)`` slab tables reassemble to the full layout table (same
    blocks, same leaf ownership, contiguous block-aligned slabs);
  * shard-local int8 encode/decode == full-buffer encode/decode — the
    sharded wire's payload bytes are IDENTICAL to ``encode_int8``'s, each
    shard's tail carries exactly its leaf window's scales (self-contained
    slab dequant), and the full scale row reconstructs byte-exactly from
    the tails;
  * per-shard wire widths account exactly for the payload + shard-LOCAL
    bitcast scale tails — the sharded wire never pays the full 4*L tail
    per shard (the pre-fix replication bug), and at ``n_shards=1`` it is
    byte-identical to the unsharded wire.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import wire
from repro.optim import flatten

from proptest import draw_codec, draw_param_tree, sweep


def _layout_for(tree, bs, shards):
    return flatten.FlatLayout.for_tree(tree, block_size=bs, shards=shards)


def _draw_case(rng):
    tree, j = draw_param_tree(rng)
    bs = int(rng.choice([128, 256, 1024, 65536]))
    n_shards = int(rng.choice([1, 2, 4, 8]))
    return tree, j, bs, n_shards


# ------------------------------------------------------------ round trip ----
def test_pack_unpack_roundtrip_randomized():
    def prop(rng, i):
        tree, j, bs, n_shards = _draw_case(rng)
        lay = _layout_for(tree, bs, n_shards)
        buf = lay.pack(tree)
        assert buf.shape == (j, lay.total)
        back = lay.unpack(buf)
        for a, b in zip(tree, back):
            assert b.dtype == a.dtype
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32))

    sweep(prop, cases=20, seed=31)


def test_padding_stays_zero_randomized():
    def prop(rng, i):
        tree, j, bs, n_shards = _draw_case(rng)
        lay = _layout_for(tree, bs, n_shards)
        buf = np.asarray(lay.pack(tree))
        pad_mask = np.ones((lay.total,), bool)
        for lf in lay.leaves:
            pad_mask[lf.offset:lf.offset + lf.size] = False
        assert (buf[:, pad_mask] == 0).all()
        # shard alignment never loses elements: padded total covers every
        # true element and divides the shard grid
        assert lay.total % (bs * n_shards) == 0
        assert sum(lf.size for lf in lay.leaves) <= lay.total

    sweep(prop, cases=20, seed=32)


# ----------------------------------------------------------- shard tables ----
def test_shard_tables_reassemble_to_full_table():
    def prop(rng, i):
        tree, j, bs, n_shards = _draw_case(rng)
        lay = _layout_for(tree, bs, n_shards)
        slay = lay.shard(n_shards)
        assert slay.n_shards == n_shards
        assert slay.shard_total * n_shards == lay.total
        assert slay.shard_total % bs == 0
        # slabs tile the flat axis contiguously on block boundaries
        starts = [s.start for s in slay.shards]
        assert starts == [k * slay.shard_total for k in range(n_shards)]
        # concatenated per-shard tables == the full block->leaf table
        reassembled = np.concatenate(
            [s.block_leaf for s in slay.shards]) if slay.blocks_per_shard \
            else np.zeros((0,), np.int32)
        np.testing.assert_array_equal(reassembled, lay.block_leaf)
        # each shard's leaf range is the contiguous span its blocks cover
        for s in slay.shards:
            if s.block_leaf.size:
                assert s.leaf_lo == int(s.block_leaf[0])
                assert s.leaf_hi == int(s.block_leaf[-1])
                assert s.leaf_lo <= s.leaf_hi < lay.num_leaves

    sweep(prop, cases=20, seed=33)


def test_shard_requires_divisible_blocks():
    tree = [jnp.zeros((2, 300), jnp.float32)]
    lay = flatten.FlatLayout.for_tree(tree, block_size=128)  # 3 blocks
    with pytest.raises(ValueError):
        lay.shard(2)
    lay2 = flatten.FlatLayout.for_tree(tree, block_size=128, shards=2)
    assert lay2.num_blocks % 2 == 0
    lay2.shard(2)


# ------------------------------------------------------- sharded int8 wire ----
def test_shard_local_int8_encode_matches_full_buffer():
    def prop(rng, i):
        tree, j, bs, n_shards = _draw_case(rng)
        lay = _layout_for(tree, bs, n_shards)
        slay = lay.shard(n_shards)
        buf = lay.pack(tree)

        full_wire = lay.encode_int8(buf)
        full_payload, full_scales = lay.decode_split(full_wire)
        sh_wire = slay.encode_int8(buf)
        assert sh_wire.dtype == jnp.int8
        assert sh_wire.shape == (j, n_shards * slay.wire_width("int8"))

        # payload bytes identical to the full-buffer encode, per shard
        w = slay.wire_width("int8")
        rows = np.asarray(sh_wire).reshape(j, n_shards, w)
        for s in slay.shards:
            np.testing.assert_array_equal(
                rows[:, s.index, :slay.shard_total],
                np.asarray(full_payload)[:, s.start:s.start + s.size])
            # shard-local tail: exactly the full-buffer scales of THIS
            # slab's leaf window (tail_gather order) — the slab can
            # dequantize itself without any other shard's bytes
            tail = jnp.asarray(rows[:, s.index, slay.shard_total:]
                               .reshape(j, slay.tail_leaves, 4))
            np.testing.assert_array_equal(
                np.asarray(jax.lax.bitcast_convert_type(tail, jnp.float32)),
                np.asarray(full_scales)[:, slay.tail_gather[s.index]])

        # split_wire reassembles the identical (payload, scales) pair
        payload, scales = slay.split_wire(sh_wire)
        np.testing.assert_array_equal(np.asarray(payload),
                                      np.asarray(full_payload))
        np.testing.assert_array_equal(np.asarray(scales),
                                      np.asarray(full_scales))
        # float wire carries no tails and passes through untouched
        p2, s2 = slay.split_wire(buf)
        assert s2 is None and p2 is buf

    sweep(prop, cases=15, seed=34)


def test_sharded_wire_width_accounting():
    def prop(rng, i):
        tree, j, bs, n_shards = _draw_case(rng)
        lay = _layout_for(tree, bs, n_shards)
        slay = lay.shard(n_shards)
        assert slay.wire_width("none") == slay.shard_total
        assert slay.wire_width("int8") == \
            slay.shard_total + 4 * slay.tail_leaves
        # int8: full payload + shard-LOCAL scale tails; float: same bytes
        # as the unsharded wire
        assert slay.wire_bytes("int8") == \
            lay.total + 4 * slay.tail_leaves * n_shards
        assert slay.wire_bytes("none") == \
            lay.total * jnp.dtype(lay.wire_dtype).itemsize
        # regression pin on the replication bug: the uniform window never
        # exceeds the full leaf count, so the sharded tail bytes are
        # bounded by (and at n_shards=1 equal to) the old per-shard-full
        # format's — and every leaf still appears in some window
        assert slay.tail_leaves <= lay.num_leaves
        if n_shards == 1:
            assert slay.tail_leaves == lay.num_leaves
            assert slay.wire_bytes("int8") == lay.total + 4 * lay.num_leaves
        covered = set(np.asarray(slay.tail_gather).ravel().tolist())
        assert covered == set(range(lay.num_leaves))

    sweep(prop, cases=20, seed=35)


# ------------------------------------------------------- wire codecs ----
_FP8_MANT = {"fp8_e4m3": 3, "fp8_e5m2": 2}


def _dequant_bound(codec, scales):
    """Per-element |dequant - original| bound of a codec's quantization."""
    if codec.name == "int8":
        sv = np.asarray(codec.layout.scale_vector(scales))
        return 0.5 * sv + 1e-7
    m = _FP8_MANT[codec.name]
    sv = np.asarray(codec.scale_vector(scales))
    # half-ulp relative error on normals (bounded by absmax = s * fp8_max)
    # plus one scale unit covering the subnormal range near zero
    return sv * (codec.fp8_max * 2.0 ** -(m + 1) + 1.0) + 1e-9


def _dequant(codec, payload, scales):
    sv = (codec.layout.scale_vector(scales) if codec.name == "int8"
          else codec.scale_vector(scales))
    return np.asarray(payload.astype(jnp.float32) * sv)


def test_codec_roundtrip_randomized():
    """Satellite pin: every codec round-trips every randomized tree (odd /
    scalar / empty leaves, mixed bf16/f32, block 128..64k), sharded and
    unsharded, within its format's quantization bound."""
    def prop(rng, i):
        tree, j, bs, n_shards = _draw_case(rng)
        lay = _layout_for(tree, bs, n_shards)
        slay = lay.shard(n_shards)
        buf = lay.pack(tree)
        name = draw_codec(rng)
        for sl in (None, slay):
            c = wire.get_codec(name, lay, sl)
            w = c.encode(buf)
            assert w.shape == (j, c.wire_width), (name, w.shape)
            assert w.dtype == c.wire_dtype
            assert c.wire_bytes() == \
                c.wire_width * jnp.dtype(c.wire_dtype).itemsize
            payload, scales = c.decode(w)
            assert payload.shape == buf.shape
            assert payload.dtype == c.payload_dtype
            if name == "native":
                assert scales is None
                np.testing.assert_array_equal(np.asarray(payload),
                                              np.asarray(buf))
                continue
            spec = c.kernel_dequant_spec()
            assert scales.shape == (j, spec.scale_width), (name, spec)
            assert spec.per_block == name.startswith("fp8")
            err = np.abs(_dequant(c, payload, scales) - np.asarray(buf))
            assert (err <= _dequant_bound(c, scales)).all(), \
                (name, sl is not None, float(err.max()))
            # probe-side unpack dequantizes to the same values per leaf
            back = c.unpack(payload, scales)
            for orig, got in zip(tree, back):
                assert got.dtype == orig.dtype
                a = np.asarray(orig, np.float32)
                b = np.asarray(got, np.float32)
                # extra 2^-8 relative slack: bf16 leaves re-round on cast
                bound = (_dequant_bound(c, scales).max()
                         + np.abs(a) * 2.0 ** -8 + 1e-7)
                assert (np.abs(a - b) <= bound).all(), name

    sweep(prop, cases=20, seed=36)


def _legacy_int8_wire(lay, buf):
    """The PRE-CODEC int8 tail format, reimplemented from scratch — the
    independent oracle pinning ``int8`` via the codec byte-identical to
    the format main shipped before the wire subsystem existed."""
    b = np.asarray(buf, np.float32)
    j = b.shape[0]
    cols = []
    for lf in lay.leaves:
        seg = b[:, lf.offset:lf.offset + lf.size]
        amax = np.abs(seg).max(axis=1, initial=0.0)
        cols.append(np.maximum(amax, np.float32(1e-12)) / np.float32(127.0))
    scales = np.stack(cols, axis=1).astype(np.float32)
    sv = np.repeat(scales[:, lay.block_leaf], lay.block_size,
                   axis=1)[:, :lay.total]
    q = np.clip(np.round(b / sv), -127, 127).astype(np.int8)
    tail = scales.view(np.int8).reshape(j, -1)      # little-endian bitcast
    return q, scales, np.concatenate([q, tail], axis=1)


def test_int8_codec_byte_identical_to_pre_refactor_tail_format():
    """Acceptance pin: routing int8 through the codec subsystem produces
    byte-identical wire payloads — checked against a from-scratch
    reimplementation of the old tail format, NOT against the moved code."""
    def prop(rng, i):
        tree, j, bs, n_shards = _draw_case(rng)
        lay = _layout_for(tree, bs, n_shards)
        buf = lay.pack(tree)
        q, scales, legacy = _legacy_int8_wire(lay, buf)
        got = np.asarray(wire.get_codec("int8", lay).encode(buf))
        np.testing.assert_array_equal(got, legacy)
        # sharded message: same payload slabs, shard-LOCAL scale tails
        # (each slab carries only its leaf window, little-endian bitcast)
        slay = lay.shard(n_shards)
        got_s = np.asarray(wire.get_codec("int8", lay, slay).encode(buf))
        w = slay.wire_width("int8")
        rows = got_s.reshape(j, slay.n_shards, w)
        tail = scales.view(np.int8).reshape(j, lay.num_leaves, 4)
        for s in slay.shards:
            np.testing.assert_array_equal(
                rows[:, s.index, :slay.shard_total],
                q[:, s.start:s.start + s.size])
            np.testing.assert_array_equal(
                rows[:, s.index, slay.shard_total:],
                tail[:, slay.tail_gather[s.index]].reshape(j, -1))

    sweep(prop, cases=15, seed=37)


def test_sharded_codec_payload_bytes_match_unsharded():
    """Satellite pin: per codec, the sharded message carries the SAME
    payload bytes as the unsharded one (slab-sliced) and decodes to the
    identical (payload, scales) pair — resharding never re-quantizes."""
    def prop(rng, i):
        tree, j, bs, n_shards = _draw_case(rng)
        lay = _layout_for(tree, bs, n_shards)
        slay = lay.shard(n_shards)
        buf = lay.pack(tree)
        for name in wire.WIRE_CODECS:
            c_full = wire.get_codec(name, lay)
            c_sh = wire.get_codec(name, lay, slay)
            p_full, s_full = c_full.decode(c_full.encode(buf))
            wire_sh = c_sh.encode(buf)
            p_sh, s_sh = c_sh.decode(wire_sh)
            np.testing.assert_array_equal(
                np.asarray(p_sh, np.float32), np.asarray(p_full, np.float32))
            if s_full is None:
                assert s_sh is None
                continue
            np.testing.assert_array_equal(np.asarray(s_sh),
                                          np.asarray(s_full))
            # slab payload bytes == the unsharded payload slice
            rows = np.asarray(wire_sh).reshape(j, n_shards,
                                               c_sh.shard_wire_width)
            raw_full = np.asarray(
                c_full.encode(buf))[:, :lay.total]     # quantized bytes
            for s in slay.shards:
                np.testing.assert_array_equal(
                    rows[:, s.index, :slay.shard_total],
                    raw_full[:, s.start:s.start + s.size])

    sweep(prop, cases=10, seed=38)


def test_codec_wire_width_accounting():
    """Wire widths/bytes per codec: native = itemsize*total, int8 = 1
    B/param + shard-local 4 B/leaf-window tails, fp8 = 1 B/param +
    4 B/block with scales splitting exactly across shards (zero sharding
    overhead)."""
    def prop(rng, i):
        tree, j, bs, n_shards = _draw_case(rng)
        lay = _layout_for(tree, bs, n_shards)
        slay = lay.shard(n_shards)
        nat = wire.get_codec("native", lay)
        assert nat.wire_bytes() == \
            lay.total * jnp.dtype(lay.wire_dtype).itemsize
        i8 = wire.get_codec("int8", lay)
        assert i8.wire_bytes() == lay.total + 4 * lay.num_leaves
        i8s = wire.get_codec("int8", lay, slay)
        assert i8s.wire_bytes() == \
            lay.total + 4 * slay.tail_leaves * n_shards
        # the old bug replicated the full 4*L tail in every shard — the
        # shard-local format never exceeds that and matches it at 1 shard
        assert i8s.wire_bytes() <= lay.total + 4 * lay.num_leaves * n_shards
        if n_shards == 1:
            assert i8s.wire_bytes() == i8.wire_bytes()
        for name in ("fp8_e4m3", "fp8_e5m2"):
            f8 = wire.get_codec(name, lay)
            f8s = wire.get_codec(name, lay, slay)
            assert f8.wire_bytes() == lay.total + 4 * lay.num_blocks
            assert f8s.wire_bytes() == f8.wire_bytes()   # scales split
            assert f8s.shard_wire_width * n_shards == f8.wire_width
        # the ledger sizes its rows off the same accounting
        from repro.async_exec.ledger import wire_width as ledger_width
        for name in wire.WIRE_CODECS:
            assert ledger_width(lay, name, slay) == \
                wire.get_codec(name, lay, slay).wire_width

    sweep(prop, cases=15, seed=39)


def test_empty_and_scalar_leaves_survive_int8():
    tree = [jnp.zeros((3, 0), jnp.float32),            # empty
            jnp.asarray(np.random.default_rng(0).normal(size=(3,))
                        .astype(np.float32)),          # scalar per node
            jnp.asarray(np.random.default_rng(1).normal(size=(3, 257))
                        .astype(np.float32))]
    lay = flatten.FlatLayout.for_tree(tree, block_size=128, shards=2)
    buf = lay.pack(tree)
    payload, scales = lay.decode_split(lay.encode_int8(buf))
    back = lay.unpack(payload, scales=scales)
    assert back[0].shape == (3, 0)
    amax = float(np.abs(np.asarray(tree[2])).max())
    np.testing.assert_allclose(np.asarray(back[2]), np.asarray(tree[2]),
                               atol=amax / 127.0 + 1e-6)
    slay = lay.shard(2)
    p2, s2 = slay.split_wire(slay.encode_int8(buf))
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(payload))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(scales))
