"""Property-test harness for the shard-aware flat layout (repro.optim.flatten).

Randomized pytrees (odd leaf sizes, mixed bf16/f32 dtypes, empty and scalar
leaves, block sizes 128..64k) drive four pinned properties:

  * pack -> unpack round-trips exactly, with zero-filled padding;
  * ``shard(n)`` slab tables reassemble to the full layout table (same
    blocks, same leaf ownership, contiguous block-aligned slabs);
  * shard-local int8 encode/decode == full-buffer encode/decode — the
    sharded wire's payload bytes are IDENTICAL to ``encode_int8``'s and
    every shard decodes with only its own slab bytes;
  * per-shard wire widths account exactly for the payload + per-shard
    bitcast scale tails.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import flatten

from proptest import draw_param_tree, sweep


def _layout_for(tree, bs, shards):
    return flatten.FlatLayout.for_tree(tree, block_size=bs, shards=shards)


def _draw_case(rng):
    tree, j = draw_param_tree(rng)
    bs = int(rng.choice([128, 256, 1024, 65536]))
    n_shards = int(rng.choice([1, 2, 4, 8]))
    return tree, j, bs, n_shards


# ------------------------------------------------------------ round trip ----
def test_pack_unpack_roundtrip_randomized():
    def prop(rng, i):
        tree, j, bs, n_shards = _draw_case(rng)
        lay = _layout_for(tree, bs, n_shards)
        buf = lay.pack(tree)
        assert buf.shape == (j, lay.total)
        back = lay.unpack(buf)
        for a, b in zip(tree, back):
            assert b.dtype == a.dtype
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32))

    sweep(prop, cases=20, seed=31)


def test_padding_stays_zero_randomized():
    def prop(rng, i):
        tree, j, bs, n_shards = _draw_case(rng)
        lay = _layout_for(tree, bs, n_shards)
        buf = np.asarray(lay.pack(tree))
        pad_mask = np.ones((lay.total,), bool)
        for lf in lay.leaves:
            pad_mask[lf.offset:lf.offset + lf.size] = False
        assert (buf[:, pad_mask] == 0).all()
        # shard alignment never loses elements: padded total covers every
        # true element and divides the shard grid
        assert lay.total % (bs * n_shards) == 0
        assert sum(lf.size for lf in lay.leaves) <= lay.total

    sweep(prop, cases=20, seed=32)


# ----------------------------------------------------------- shard tables ----
def test_shard_tables_reassemble_to_full_table():
    def prop(rng, i):
        tree, j, bs, n_shards = _draw_case(rng)
        lay = _layout_for(tree, bs, n_shards)
        slay = lay.shard(n_shards)
        assert slay.n_shards == n_shards
        assert slay.shard_total * n_shards == lay.total
        assert slay.shard_total % bs == 0
        # slabs tile the flat axis contiguously on block boundaries
        starts = [s.start for s in slay.shards]
        assert starts == [k * slay.shard_total for k in range(n_shards)]
        # concatenated per-shard tables == the full block->leaf table
        reassembled = np.concatenate(
            [s.block_leaf for s in slay.shards]) if slay.blocks_per_shard \
            else np.zeros((0,), np.int32)
        np.testing.assert_array_equal(reassembled, lay.block_leaf)
        # each shard's leaf range is the contiguous span its blocks cover
        for s in slay.shards:
            if s.block_leaf.size:
                assert s.leaf_lo == int(s.block_leaf[0])
                assert s.leaf_hi == int(s.block_leaf[-1])
                assert s.leaf_lo <= s.leaf_hi < lay.num_leaves

    sweep(prop, cases=20, seed=33)


def test_shard_requires_divisible_blocks():
    tree = [jnp.zeros((2, 300), jnp.float32)]
    lay = flatten.FlatLayout.for_tree(tree, block_size=128)  # 3 blocks
    with pytest.raises(ValueError):
        lay.shard(2)
    lay2 = flatten.FlatLayout.for_tree(tree, block_size=128, shards=2)
    assert lay2.num_blocks % 2 == 0
    lay2.shard(2)


# ------------------------------------------------------- sharded int8 wire ----
def test_shard_local_int8_encode_matches_full_buffer():
    def prop(rng, i):
        tree, j, bs, n_shards = _draw_case(rng)
        lay = _layout_for(tree, bs, n_shards)
        slay = lay.shard(n_shards)
        buf = lay.pack(tree)

        full_wire = lay.encode_int8(buf)
        full_payload, full_scales = lay.decode_split(full_wire)
        sh_wire = slay.encode_int8(buf)
        assert sh_wire.dtype == jnp.int8
        assert sh_wire.shape == (j, n_shards * slay.wire_width("int8"))

        # payload bytes identical to the full-buffer encode, per shard
        w = slay.wire_width("int8")
        rows = np.asarray(sh_wire).reshape(j, n_shards, w)
        for s in slay.shards:
            np.testing.assert_array_equal(
                rows[:, s.index, :slay.shard_total],
                np.asarray(full_payload)[:, s.start:s.start + s.size])
            # every shard's tail carries the exact full-buffer scales —
            # decode needs no other shard's bytes
            tail = jnp.asarray(rows[:, s.index, slay.shard_total:]
                               .reshape(j, lay.num_leaves, 4))
            np.testing.assert_array_equal(
                np.asarray(jax.lax.bitcast_convert_type(tail, jnp.float32)),
                np.asarray(full_scales))

        # split_wire reassembles the identical (payload, scales) pair
        payload, scales = slay.split_wire(sh_wire)
        np.testing.assert_array_equal(np.asarray(payload),
                                      np.asarray(full_payload))
        np.testing.assert_array_equal(np.asarray(scales),
                                      np.asarray(full_scales))
        # float wire carries no tails and passes through untouched
        p2, s2 = slay.split_wire(buf)
        assert s2 is None and p2 is buf

    sweep(prop, cases=15, seed=34)


def test_sharded_wire_width_accounting():
    def prop(rng, i):
        tree, j, bs, n_shards = _draw_case(rng)
        lay = _layout_for(tree, bs, n_shards)
        slay = lay.shard(n_shards)
        assert slay.wire_width("none") == slay.shard_total
        assert slay.wire_width("int8") == \
            slay.shard_total + 4 * lay.num_leaves
        # int8: full payload + one scale tail PER shard; float: same bytes
        # as the unsharded wire
        assert slay.wire_bytes("int8") == \
            lay.total + 4 * lay.num_leaves * n_shards
        assert slay.wire_bytes("none") == \
            lay.total * jnp.dtype(lay.wire_dtype).itemsize

    sweep(prop, cases=20, seed=35)


def test_empty_and_scalar_leaves_survive_int8():
    tree = [jnp.zeros((3, 0), jnp.float32),            # empty
            jnp.asarray(np.random.default_rng(0).normal(size=(3,))
                        .astype(np.float32)),          # scalar per node
            jnp.asarray(np.random.default_rng(1).normal(size=(3, 257))
                        .astype(np.float32))]
    lay = flatten.FlatLayout.for_tree(tree, block_size=128, shards=2)
    buf = lay.pack(tree)
    payload, scales = lay.decode_split(lay.encode_int8(buf))
    back = lay.unpack(payload, scales=scales)
    assert back[0].shape == (3, 0)
    amax = float(np.abs(np.asarray(tree[2])).max())
    np.testing.assert_allclose(np.asarray(back[2]), np.asarray(tree[2]),
                               atol=amax / 127.0 + 1e-6)
    slay = lay.shard(2)
    p2, s2 = slay.split_wire(slay.encode_int8(buf))
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(payload))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(scales))
