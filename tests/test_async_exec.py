"""Tests for the bounded-staleness async executor (repro.async_exec).

Three layers:
  * host layer — the RoundClock event model (arrival freshness, straggler
    cadence, wall-clock conventions) and aged-out straggler detection,
    no devices needed;
  * engine pins (subprocess, 8 fake devices) —
      - max_staleness=0 through the executor is bit-identical to the sync
        fused round (the ISSUE acceptance pin),
      - a staleness round with gating, revival and zero-kick absorption
        matches the jnp reference path at wire precision — params are
        stored bf16 and the int8 wire re-quantizes each round, so the pin
        is allclose(rtol=1e-2, atol=wire LSB), see the test docstring
        (fused == "dense" on a gated round, the satellite pin, for both
        the stale-gate kick and the scheduler kick),
      - ages tick / gate / revive as the arrival schedule dictates,
      - the scheduler-kick path (pending weights parked one round, absorbed
        from the next round's wire) matches the reference on a complete
        graph where round_robin really gates chords;
  * ledger layer — zero-init is never consumed, buffers hold bytes.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.async_exec import RoundClock, straggler_compute

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------- host layer ----
def test_clock_homogeneous_fleet_everything_fresh():
    clock = RoundClock(compute_s=np.ones(4), wire_s=0.1,
                       offsets=(1, 3))
    for _ in range(5):
        arrivals, advance = clock.tick()
        assert advance.all()
        assert arrivals.all()           # every edge fresh every tick
    assert clock.rounds_done.tolist() == [5, 5, 5, 5]


def test_clock_straggler_cadence_and_staleness_alternates():
    j = 4
    clock = RoundClock(compute_s=straggler_compute(j, factor=2.0),
                       wire_s=0.1, offsets=(1, 3))
    fresh_from_straggler = []
    for t in range(8):
        arrivals, advance = clock.tick()
        # node 0 advances every other tick
        assert advance[0] == (t % 2 == 1)
        assert advance[1:].all()
        # receiver 1 reads node 0 over offset 3 ((1+3)%4 == 0)
        fresh_from_straggler.append(bool(arrivals[1][1]))
    # first read is fresh, then alternates with the 2x cadence
    assert fresh_from_straggler[0] is True
    assert sum(fresh_from_straggler) >= 3
    assert not all(fresh_from_straggler)
    assert clock.rounds_done[0] * 2 == clock.rounds_done[1]


def test_clock_wall_conventions():
    clock = RoundClock(compute_s=straggler_compute(4, factor=2.0),
                       wire_s=0.5, offsets=(1,))
    assert clock.sync_round_s == 2.5          # barrier + serialized wire
    assert clock.tick_s == 1.0                # fastest cadence
    for _ in range(3):
        clock.tick()
    assert clock.time_s == pytest.approx(3.0)


def test_first_read_always_fresh_so_zero_ledger_never_consumed():
    # even a huge wire latency only delays SENDS; the initial params count
    # as a landed send id 0, so every edge's first read is fresh
    clock = RoundClock(compute_s=np.ones(3), wire_s=50.0, offsets=(1, 2))
    arrivals, advance = clock.tick()
    assert advance.all() and arrivals.all()


def test_aged_out_nodes_reads_topology_clocks():
    from repro.core.graph import build_graph
    from repro.runtime import aged_out_nodes
    from repro.topology import TopologyConfig, TopologyRuntime

    g = build_graph("ring", 5)
    rt = TopologyRuntime(g, TopologyConfig(scheduler="stale",
                                           max_staleness=1))
    st = rt.init_state()
    age = np.zeros((5, 5), np.int32)
    age[:, 2] = 60                      # everyone's payload FROM node 2 is
    age[2, :] = 60                      # ancient, and so is its inbox
    np.fill_diagonal(age, 0)
    st = st._replace(age=np.asarray(age))
    assert aged_out_nodes(st, max_staleness=1) == [2]
    # patience: recent enough edges keep the node
    st2 = st._replace(age=np.asarray(age // 30))
    assert aged_out_nodes(st2, max_staleness=1) == []


def test_async_config_validation():
    from repro.async_exec import AsyncConfig
    with pytest.raises(ValueError):
        AsyncConfig(max_staleness=-1)
    with pytest.raises(ValueError):
        AsyncConfig(stale_gamma=-0.1)
    assert AsyncConfig().max_staleness == 1


def test_wire_ledger_shapes_and_dtypes():
    import jax.numpy as jnp
    from repro.async_exec import init_wire_ledger, wire_width
    from repro.optim import flatten

    tree = {"a": np.zeros((4, 40), np.float32),
            "b": np.zeros((4, 7), np.float32)}
    lay = flatten.FlatLayout.for_tree(tree, block_size=16)
    led = init_wire_ledger(lay, deg=2, num_nodes=4, compression="int8")
    assert led.wires.shape == (2, 4, wire_width(lay, "int8"))
    assert led.wires.dtype == jnp.int8
    led_f = init_wire_ledger(lay, deg=2, num_nodes=4, compression="none")
    assert led_f.wires.shape == (2, 4, lay.total)


# ----------------------------------------------- engine layer (8 dev) ----
_ENGINE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.async_exec import AsyncConfig, AsyncExecutor
from repro.configs import get_reduced_config
from repro.core.penalty import PenaltyConfig
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.optim import ConsensusConfig, ConsensusTrainer
from repro.optim.adamw import AdamWConfig
from repro.topology import TopologyConfig

out = {}
mesh = make_mesh((4, 2, 1), ("pod", "data", "model"))
cfg = get_reduced_config("qwen3-4b")
model = build_model(cfg)
data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  batch_per_node=2, num_nodes=4))
probe = data.batch(0, probe=True)

def make(async_cfg=None, dyn=None, fused=True, compression="none",
         topology="ring", sharded=False, penalty=None):
    return ConsensusTrainer(
        model, mesh, adamw=AdamWConfig(lr=1e-2),
        consensus=ConsensusConfig(
            penalty=penalty or PenaltyConfig(scheme="nap", eta0=0.1),
            topology=topology, local_steps=1, use_fused_kernel=fused,
            compression=compression,
            dyn_topology=dyn or TopologyConfig(),
            async_exec=async_cfg, shard_consensus=sharded))

def flat(st):
    return ([np.asarray(x) for x in jax.tree_util.tree_leaves(st.params)]
            + [np.asarray(st.lam), np.asarray(st.theta_bar_prev),
               np.asarray(st.penalty.eta)])

def flatu(tr, st):
    # layout-independent view (the sharded layout pads the flat total):
    # params + per-leaf lam/bar views + penalties
    return ([np.asarray(x, np.float32)
             for x in jax.tree_util.tree_leaves(st.params)]
            + [np.asarray(x) for x in jax.tree_util.tree_leaves(
                tr.layout.unpack(st.lam))]
            + [np.asarray(x) for x in jax.tree_util.tree_leaves(
                tr.layout.unpack(st.theta_bar_prev))]
            + [np.asarray(st.penalty.eta)])

def fresh_state(tr):
    st = tr.init_state(jax.random.PRNGKey(0))
    st, _ = jax.jit(tr.train_step)(st, data.batch(0))
    return st

base = make()
state0 = base.init_state(jax.random.PRNGKey(0))
state0, _ = jax.jit(base.train_step)(state0, data.batch(0))

# --- 1. max_staleness=0 through the executor == sync fused round --------
st_sync = jax.tree_util.tree_map(lambda x: x, state0)
cons = jax.jit(base.consensus_step)
st_sync, m_sync = cons(st_sync, probe)
st_sync, m_sync = cons(st_sync, probe)

tr0 = make(async_cfg=AsyncConfig(max_staleness=0))
st0 = tr0.init_state(jax.random.PRNGKey(0))
st0, _ = jax.jit(tr0.train_step)(st0, data.batch(0))
ex0 = AsyncExecutor(tr0)
st0, m0 = ex0.consensus_round(st0, probe)
st0, m0 = ex0.consensus_round(st0, probe)
out["n0_bit_identical"] = all(
    np.array_equal(a, b) for a, b in zip(flat(st_sync), flat(st0)))
out["n0_metrics_equal"] = all(
    float(m_sync[k]) == float(m0[k]) for k in m_sync)

# --- 1b. SHARDED max_staleness=0 through the executor == sharded sync ----
# (the max_staleness=0 == sync invariant re-established on the slab path)
trss = make(sharded=True)
st_ss = fresh_state(trss)
conss = jax.jit(trss.consensus_step)
st_ss, m_ss = conss(st_ss, probe)
st_ss, m_ss = conss(st_ss, probe)
tr0s = make(async_cfg=AsyncConfig(max_staleness=0), sharded=True)
st0s = fresh_state(tr0s)
ex0s = AsyncExecutor(tr0s)
st0s, m0s = ex0s.consensus_round(st0s, probe)
st0s, m0s = ex0s.consensus_round(st0s, probe)
out["n0_sharded_bit_identical"] = all(
    np.array_equal(a, b) for a, b in zip(flat(st_ss), flat(st0s)))
out["n0_sharded_metrics_equal"] = all(
    float(m_ss[k]) == float(m0s[k]) for k in m_ss)

# --- 2. staleness round: fused == reference on gating + revival ---------
# deterministic arrival schedule, N=1, int8 wire: sender 0's payloads land
# only every 3rd tick => edges reading node 0 age 0,1,2(gated -> kick),0...
# COMPLETE graph so the straggler has non-backbone chords: those are the
# edges the stale scheduler also drops from the mask (backbone never is),
# i.e. the double-absorption scenario the kick bookkeeping must dodge.
def arrivals_for(tr, tick):
    deg = len(tr.offsets)
    j = tr.num_nodes
    idx = np.arange(j)
    arr = np.zeros((deg, j), bool)
    for d, off in enumerate(tr.offsets):
        senders = (idx + off) % j
        arr[d] = (senders != 0) | (tick % 3 == 0)
    return jnp.asarray(arr)

acfg = AsyncConfig(max_staleness=1)
dyn = TopologyConfig(scheduler="stale", max_staleness=1)
for compression in ("none", "int8"):
    stats = {}
    for fused in (True, False):
        tr = make(async_cfg=acfg, dyn=dyn, fused=fused,
                  compression=compression, topology="complete")
        st = tr.init_state(jax.random.PRNGKey(0))
        st, _ = jax.jit(tr.train_step)(st, data.batch(0))
        step = jax.jit(tr.consensus_step_async)
        ms = []
        for t in range(5):
            st, m = step(st, probe, arrivals_for(tr, t), None)
            ms.append({k: float(v) for k, v in m.items()})
            if fused and compression == "none" and t == 2:
                # t=2 is the tick the straggler's edges age past the
                # bound: they were kick-absorbed IN-ROUND from the
                # ledger, so the stale scheduler mirroring them out of
                # the mask must NOT park a second (double-absorption)
                # kick for next round
                k = np.asarray(st.topo.kick)
                out["kick_double_absorb"] = float(
                    np.abs(k[:, 0]).sum() + np.abs(k[0, :]).sum())
        stats[fused] = (flat(st), ms, np.asarray(st.topo.age),
                        flatu(tr, st))
    # sharded stale round: same arrival schedule through the slab engine
    # (per-shard ledger rows, in-round kick absorption from local bytes)
    trs = make(async_cfg=acfg, dyn=dyn, fused=True,
               compression=compression, topology="complete", sharded=True)
    sts = fresh_state(trs)
    steps_ = jax.jit(trs.consensus_step_async)
    for t in range(5):
        sts, m_s = steps_(sts, probe, arrivals_for(trs, t), None)
    out[f"stale_sharded_err_{compression}"] = max(
        float(np.max(np.abs(a - b)))
        for a, b in zip(flatu(trs, sts), stats[True][3]))
    if compression == "int8":
        # per-shard ledger rows: each device's slab holds ONE shard's
        # wire width (payload slab + its own scale tail), not the row
        out["ledger_slab_widths"] = sorted(
            {int(s.data.shape[-1])
             for s in sts.ledger.wires.addressable_shards})
        out["ledger_slab_expected"] = trs.slayout.wire_width("int8")
        out["ledger_row_width"] = int(sts.ledger.wires.shape[-1])
    # "equal at wire precision": params are STORED bf16, so the two f32
    # paths legitimately differ by single bf16 ulps (rtol 1e-2 ~ 2-3
    # ulps); atol covers near-zero duals and, for int8, one LSB of the
    # absmax scale on the re-encoded wire
    atol = 2e-3 if compression == "int8" else 1e-4
    out[f"stale_close_{compression}"] = bool(all(
        np.allclose(a, b, rtol=1e-2, atol=atol)
        for a, b in zip(stats[True][0], stats[False][0])))
    out[f"stale_fused_vs_ref_err_{compression}"] = max(
        float(np.max(np.abs(a - b)))
        for a, b in zip(stats[True][0], stats[False][0]))
    out[f"stale_metric_err_{compression}"] = max(
        abs(a[k] - b[k]) / (abs(b[k]) + 1.0)
        for a, b in zip(stats[True][1], stats[False][1]) for k in a)
    if compression == "none":
        # ages of edges reading node 0 follow the 0,1,2,0,... schedule;
        # at tick 4 (last arrivals at tick 3) they sit at 1; fresh edges
        # stay at 0
        age = stats[True][2]
        out["age_into_straggler"] = int(age[1, 0])
        out["age_fresh"] = int(age[1, 2])
        # staleness gating showed up and then healed
        out["stale_seen"] = max(m["stale_edges"] for m in stats[True][1])
        out["stale_final"] = stats[True][1][-1]["stale_edges"]
        out["age_max_seen"] = max(m["age_max"] for m in stats[True][1])

# --- 3. engine scheduler-kick: fused == reference on gated rounds -------
# round_robin on COMPLETE gates the chords every epoch (on a ring the
# backbone is the whole graph and nothing can gate), so pending kicks are
# nonzero and the kernel's absorption term actually fires.
kflat = {}
for fused in (True, False):
    trk = make(dyn=TopologyConfig(scheduler="round_robin"), fused=fused,
               topology="complete")
    stk = trk.init_state(jax.random.PRNGKey(0))
    stk, _ = jax.jit(trk.train_step)(stk, data.batch(0))
    stepk = jax.jit(trk.consensus_step)
    stk, mk = stepk(stk, probe)     # parks the kick for the gated chords
    if fused:
        out["kick_pending_nonzero"] = bool(
            np.asarray(stk.topo.kick).sum() > 0)
    stk, mk = stepk(stk, probe)     # absorbs it from this round's wire
    kflat[fused] = flat(stk)
out["sched_kick_close"] = bool(all(
    np.allclose(a, b, rtol=1e-2, atol=1e-4)
    for a, b in zip(kflat[True], kflat[False])))
out["sched_kick_fused_vs_ref_err"] = max(
    float(np.max(np.abs(a - b)))
    for a, b in zip(kflat[True], kflat[False]))

# --- 4. budget-gated topology: sharded == unsharded on gated rounds -----
# force gating: a zero initial budget exhausts every edge immediately and
# a huge gate_tol drops the residual guard, so the budget scheduler gates
# all non-backbone chords of the COMPLETE graph at the end of round 1 and
# round 2 absorbs their parked kicks — the budget-gated pin of the ISSUE.
bdyn = TopologyConfig(scheduler="budget", gate_tol=1e9)
bpen = PenaltyConfig(scheme="nap", eta0=0.1, budget_init=0.0)
for compression in ("none", "int8"):
    bflat = {}
    for sharded in (True, False):
        trb = make(dyn=bdyn, compression=compression, topology="complete",
                   sharded=sharded, penalty=bpen)
        stb = fresh_state(trb)
        stepb = jax.jit(trb.consensus_step)
        stb, mb = stepb(stb, probe)     # gates chords, parks their kicks
        if sharded:
            out[f"budget_kick_pending_{compression}"] = bool(
                np.asarray(stb.topo.kick).sum() > 0)
        stb, mb = stepb(stb, probe)     # absorbs kicks from this wire
        if sharded:
            out[f"budget_gated_active_{compression}"] = float(
                mb["active_edges"])
        bflat[sharded] = flatu(trb, stb)
    out[f"budget_sharded_err_{compression}"] = max(
        float(np.max(np.abs(a - b)))
        for a, b in zip(bflat[True], bflat[False]))
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def engine_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _ENGINE], env=env,
                          capture_output=True, text=True, timeout=1800)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_max_staleness_zero_bit_identical_to_sync(engine_results):
    assert engine_results["n0_bit_identical"] is True
    assert engine_results["n0_metrics_equal"] is True


def test_sharded_max_staleness_zero_bit_identical_to_sharded_sync(
        engine_results):
    """The max_staleness=0 == sync invariant re-established on the sharded
    engine (slab buffers, per-shard ledger): bit-identical incl. metrics."""
    assert engine_results["n0_sharded_bit_identical"] is True
    assert engine_results["n0_sharded_metrics_equal"] is True


def test_sharded_stale_round_matches_unsharded(engine_results):
    """Satellite pin: the sharded stale-topology round (gating, revival,
    in-round zero-kick from per-shard ledger rows) == the unsharded fused
    round — the per-element math is identical, so the bound is f32
    exactness, not just wire precision."""
    assert engine_results["stale_sharded_err_none"] <= 1e-5, engine_results
    assert engine_results["stale_sharded_err_int8"] <= 1e-5, engine_results


def test_sharded_ledger_rows_are_per_shard(engine_results):
    """Each device's ledger slab holds one shard's wire width (payload
    slab + its own int8 scale tail) — staleness absorption reads only
    local bytes."""
    assert engine_results["ledger_slab_widths"] == \
        [engine_results["ledger_slab_expected"]], engine_results
    assert engine_results["ledger_row_width"] > \
        engine_results["ledger_slab_expected"]      # guard: really sharded


def test_sharded_budget_gated_matches_unsharded(engine_results):
    """Satellite pin: budget-gated topology (scheduler gates the complete
    graph's chords, parks kicks, absorbs them next round) sharded ==
    unsharded for both compressions."""
    for comp in ("none", "int8"):
        assert engine_results[f"budget_kick_pending_{comp}"] is True
        assert engine_results[f"budget_gated_active_{comp}"] < 1.0
        assert engine_results[f"budget_sharded_err_{comp}"] <= 1e-5, \
            engine_results


def test_stale_round_fused_matches_reference(engine_results):
    """Satellite pin: fused == dense reference on rounds where staleness
    gates, revives and zero-kicks edges — at wire precision.

    Params are stored bf16, so the fused and reference f32 paths
    legitimately drift by single bf16 storage ulps per round (the f32
    difference crosses a bf16 rounding boundary); the int8 wire adds one
    LSB of the absmax scale per re-encode. The pin is therefore
    allclose(rtol=1e-2, atol=wire-LSB), not an absolute 1e-5 — which
    over a 5-tick schedule is luck, not correctness.
    """
    assert engine_results["stale_close_none"] is True, engine_results
    assert engine_results["stale_metric_err_none"] < 1e-4, engine_results
    assert engine_results["stale_close_int8"] is True, engine_results
    assert engine_results["stale_metric_err_int8"] < 1e-4, engine_results


def test_staleness_clocks_gate_and_revive(engine_results):
    assert engine_results["age_fresh"] == 0
    assert engine_results["age_into_straggler"] == 1
    assert engine_results["age_max_seen"] >= 2          # bound exceeded...
    assert engine_results["stale_seen"] > 0             # ...edges gated...
    assert engine_results["stale_final"] == 0.0         # ...and healed


def test_staleness_kick_not_double_absorbed(engine_results):
    """An edge kicked in-round when it aged out must not get a second
    scheduler kick when the stale scheduler drops it from the mask."""
    assert engine_results["kick_double_absorb"] == 0.0, engine_results


def test_engine_scheduler_kick_fused_matches_reference(engine_results):
    """The other half of the satellite pin: the SCHEDULER kick path (park
    at round t, absorb from round t+1's wire) in fused == reference, at
    the same wire precision as the staleness pin (bf16 storage ulps)."""
    assert engine_results["sched_kick_close"] is True, engine_results
    assert engine_results["kick_pending_nonzero"] is True
