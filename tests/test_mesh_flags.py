"""set_backend_flags() contract: append-don't-clobber, warn-no-op after init.

The dry-run (and any launcher arming the latency-hiding pipeline flags)
depends on two behaviors regression-tested here:

  1. a user-set XLA_FLAGS env var is APPENDED to, never clobbered, and a
     flag the user already spelled keeps the user's value;
  2. once any jax backend exists the env var is parsed and locked, so the
     call must warn and change nothing instead of silently writing flags
     that can no longer take effect.

Both pre-init cases run in subprocesses — the test process itself has a
live backend, which is exactly what the post-init case exercises in-proc.
"""
import os
import subprocess
import sys
import warnings

import pytest

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def _run(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    return out.stdout


def test_appends_to_user_xla_flags():
    # user flags survive verbatim and come FIRST; ours are appended
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_dump_to=/tmp/dump"
from repro.launch.mesh import ASYNC_COLLECTIVE_FLAGS, set_backend_flags
merged = set_backend_flags(async_collectives=True, host_device_count=4)
assert merged == os.environ["XLA_FLAGS"], "return value != env var"
toks = merged.split()
assert toks[0] == "--xla_dump_to=/tmp/dump", toks
for f in ASYNC_COLLECTIVE_FLAGS:
    assert f in toks, f
assert "--xla_force_host_platform_device_count=4" in toks
print("OK")
""")
    assert "OK" in out


def test_user_spelled_flag_wins():
    # the user pinned one of our flags to a different value: keep theirs,
    # never emit a duplicate name (XLA would take the last occurrence)
    out = _run("""
import os
user = "--xla_gpu_enable_latency_hiding_scheduler=false"
os.environ["XLA_FLAGS"] = user
from repro.launch.mesh import set_backend_flags
merged = set_backend_flags(async_collectives=True)
names = [f.split("=", 1)[0] for f in merged.split()]
assert names.count("--xla_gpu_enable_latency_hiding_scheduler") == 1
assert user in merged.split()
print("OK")
""")
    assert "OK" in out


def test_flags_actually_reach_backend_before_init():
    # the dry-run ordering contract: flags set pre-init take effect —
    # observable via the fake host device count
    out = _run("""
from repro.launch.mesh import set_backend_flags
set_backend_flags(async_collectives=True, host_device_count=6)
import jax
assert jax.device_count() == 6, jax.device_count()
print("OK")
""")
    assert "OK" in out


def test_noop_returns_none_without_work():
    out = _run("""
import os
from repro.launch.mesh import set_backend_flags
assert set_backend_flags(async_collectives=False) is None
assert "XLA_FLAGS" not in os.environ
print("OK")
""")
    assert "OK" in out


def test_warn_noop_after_backend_init():
    import jax
    from repro.launch.mesh import backend_initialized, set_backend_flags

    jax.devices()                               # force backend init
    assert backend_initialized()
    before = os.environ.get("XLA_FLAGS")
    with pytest.warns(RuntimeWarning, match="already locked in"):
        got = set_backend_flags(async_collectives=True)
    assert got is None
    assert os.environ.get("XLA_FLAGS") == before, \
        "post-init call must not touch XLA_FLAGS"


def test_no_warning_pre_init_paths_are_silent():
    # subprocess pre-init call must NOT warn (warning is the post-init
    # signal only)
    out = _run("""
import warnings
with warnings.catch_warnings():
    warnings.simplefilter("error")
    from repro.launch.mesh import set_backend_flags
    set_backend_flags(async_collectives=True)
print("OK")
""")
    assert "OK" in out
