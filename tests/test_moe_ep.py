"""MoE expert-parallel path vs dense reference (subprocess: needs 8 devices)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses as dc
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_reduced_config
from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib
from repro.models.params import materialize
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_reduced_config("moonshot-v1-16b-a3b")
# high capacity factor so the fixed-shape dispatch drops nothing
cfg = dc.replace(cfg, moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=32,
                                    capacity_factor=8.0))
p = materialize(jax.random.PRNGKey(0), moe_lib.moe_defs(cfg, jnp.float32))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

ref = moe_lib.moe_ref(cfg, p, x)

rules = shd.default_rules(mesh)
out = {}
with shd.use_mesh(mesh, rules):
    ep = jax.jit(lambda p_, x_: moe_lib.moe_apply(cfg, p_, x_))(p, x)
    err = float(jnp.max(jnp.abs(ep - ref)))
    out["a2a_err"] = err
    scale = float(jnp.abs(ref).max())
    out["scale"] = scale
    # decode path (replicated tokens, psum combine)
    dec = jax.jit(lambda p_, x_: moe_lib.moe_apply(cfg, p_, x_,
                                                   decode=True))(p, x)
    out["repl_err"] = float(jnp.max(jnp.abs(dec - ref)))
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def ep_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_a2a_dispatch_matches_reference(ep_results):
    """all_to_all EP (sharded tokens) == dense masked reference."""
    tol = 1e-4 * (1 + ep_results["scale"])
    assert ep_results["a2a_err"] < tol, ep_results


def test_replicated_dispatch_matches_reference(ep_results):
    """decode-path EP (replicated tokens, psum combine) == reference."""
    tol = 1e-4 * (1 + ep_results["scale"])
    assert ep_results["repl_err"] < tol, ep_results
