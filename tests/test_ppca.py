"""Tests for PPCA / D-PPCA — the paper's application layer."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import PenaltyConfig, build_graph
from repro.ppca import (DPPCA, fit_em, fit_svd, init_params,
                        max_subspace_angle, nll, subspace_angle,
                        subspace_data, turntable_sfm)
from repro.ppca import ppca as cp


@pytest.fixture(scope="module")
def synth():
    return subspace_data(4, n=200, d=12, m=3, seed=0)


def test_centralized_em_matches_svd(synth):
    x = jnp.asarray(synth.x_all, jnp.float32)
    p_svd = fit_svd(x, 3)
    p0 = init_params(jax.random.PRNGKey(0), 12, 3)
    p_em, trace = fit_em(p0, x, 300)
    ang = float(jnp.rad2deg(subspace_angle(p_em.W, p_svd.W)))
    assert ang < 0.5, ang
    assert abs(float(nll(p_em, x)) - float(nll(p_svd, x))) / abs(
        float(nll(p_svd, x))) < 1e-3


def test_em_nll_monotone_decreasing(synth):
    x = jnp.asarray(synth.x_all, jnp.float32)
    p0 = init_params(jax.random.PRNGKey(1), 12, 3)
    _, trace = fit_em(p0, x, 100)
    t = np.asarray(trace)
    # EM guarantees monotone decrease of the marginal NLL
    assert np.all(t[1:] <= t[:-1] + 1e-2), np.max(t[1:] - t[:-1])


def test_e_step_posterior_shapes(synth):
    x = jnp.asarray(synth.x_all, jnp.float32)
    p = init_params(jax.random.PRNGKey(0), 12, 3)
    st = cp.e_step(p, x)
    assert st.Ez.shape == (x.shape[0], 3)
    assert st.Ezz.shape == (x.shape[0], 3, 3)
    # Ezz - Ez Ez^T = posterior covariance: must be PSD
    cov = np.asarray(st.Ezz - st.Ez[:, :, None] * st.Ez[:, None, :])
    evs = np.linalg.eigvalsh(cov)
    assert np.all(evs > -1e-5)


def test_dppca_single_node_equals_centralized():
    data = subspace_data(1, n=200, d=12, m=3, seed=2)
    x = jnp.asarray(data.x, jnp.float32)
    eng = DPPCA(latent_dim=3, graph=build_graph("complete", 1),
                penalty_cfg=PenaltyConfig(scheme="fixed", eta0=10.0))
    st = eng.init(jax.random.PRNGKey(3), x)
    for _ in range(150):
        st, m = eng.step(st, x)
    p_svd = fit_svd(x[0], 3)
    ang = float(jnp.rad2deg(subspace_angle(st.W[0], p_svd.W)))
    assert ang < 1.0, ang
    assert abs(float(st.a[0]) - float(p_svd.a)) / float(p_svd.a) < 0.05


@pytest.mark.parametrize("scheme", ["fixed", "vp", "ap", "nap", "vp_ap",
                                    "vp_nap"])
def test_dppca_all_schemes_recover_subspace(scheme):
    J = 6
    data = subspace_data(J, n=300, d=16, m=4, seed=4)
    x = jnp.asarray(data.x)
    eng = DPPCA(latent_dim=4, graph=build_graph("complete", J),
                penalty_cfg=PenaltyConfig(scheme=scheme, eta0=10.0))
    st = eng.init(jax.random.PRNGKey(5), x)
    for _ in range(250):
        st, m = eng.step(st, x)
    ang = float(max_subspace_angle(st.W, jnp.asarray(data.W_true)))
    assert ang < 6.0, (scheme, ang)
    assert np.all(np.isfinite(np.asarray(st.W)))
    # multiplier-sum invariants (the symmetrized dual conserves these)
    assert abs(float(st.bet.sum())) < 1e-3 * (1 + float(jnp.abs(st.bet).max()))


def test_dppca_consensus_tightens():
    J = 6
    data = subspace_data(J, n=300, d=16, m=4, seed=6)
    x = jnp.asarray(data.x)
    eng = DPPCA(latent_dim=4, graph=build_graph("ring", J),
                penalty_cfg=PenaltyConfig(scheme="nap", eta0=10.0))
    st = eng.init(jax.random.PRNGKey(7), x)
    r_early = r_late = None
    for it in range(500):
        st, m = eng.step(st, x)
        if it == 10:
            r_early = float(m["r_max"])
        r_late = float(m["r_max"])
    # ring topologies converge slowly (paper Fig. 2d) but do converge
    assert r_late < min(0.1, r_early * 0.1), (r_early, r_late)


def test_sfm_transposed_layout_recovers_structure():
    """D-PPCA on the turntable: consensus W must span the true 3D structure."""
    sfm = turntable_sfm(num_cameras=5, frames=30, points=60, seed=0)
    x = jnp.asarray(sfm.x_nodes)  # [5, 12, 60]: samples=frame-rows, dim=points
    eng = DPPCA(latent_dim=3, graph=build_graph("complete", 5),
                penalty_cfg=PenaltyConfig(scheme="nap", eta0=10.0))
    st = eng.init(jax.random.PRNGKey(8), x)
    for _ in range(300):
        st, _ = eng.step(st, x)
    # centralized SVD structure: top-3 right singular vectors of measurements
    p_ref = fit_svd(jnp.asarray(sfm.measurements), 3)   # W_ref: [N, 3]
    ang = float(max_subspace_angle(st.W, p_ref.W))
    assert ang < 10.0, ang


def test_subspace_angle_properties():
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(10, 3)).astype(np.float32))
    # identical subspaces -> 0; rotated basis -> still 0
    R = jnp.asarray(np.linalg.qr(rng.normal(size=(3, 3)))[0].astype(np.float32))
    # float32 QR/SVD noise: ~3e-4 rad (0.02 deg)
    assert float(subspace_angle(W, W)) < 2e-3
    assert float(subspace_angle(W, W @ R)) < 2e-3
    # orthogonal complement direction -> 90 degrees for rank-1
    a = jnp.asarray([[1.0], [0.0]])
    b = jnp.asarray([[0.0], [1.0]])
    assert abs(float(subspace_angle(a, b)) - np.pi / 2) < 1e-6
