"""Integration tests for the consensus-ADMM distributed trainer.

Run on 8 fake CPU devices (set in conftest-free fashion: these tests spawn
subprocesses? No — the device count must be set before jax init, so this
module is SKIPPED unless the harness exported the flag; tests/conftest.py
does NOT set it globally per the dry-run spec. A dedicated pytest plugin
spawns one subprocess for this module instead).
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced_config
from repro.models import build_model
from repro.optim import ConsensusConfig, ConsensusTrainer
from repro.optim.adamw import AdamWConfig
from repro.core.penalty import PenaltyConfig
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_mesh

out = {}
mesh = make_mesh((2,2,2), ("pod","data","model"))

# --- dense arch: loss decreases, consensus keeps replicas close ---------
cfg = get_reduced_config("qwen3-4b")
model = build_model(cfg)
tr = ConsensusTrainer(model, mesh, adamw=AdamWConfig(lr=1e-2),
                      consensus=ConsensusConfig(
                          penalty=PenaltyConfig(scheme="nap", eta0=0.1),
                          topology="ring", local_steps=2))
state = tr.init_state(jax.random.PRNGKey(0))
data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  batch_per_node=4, num_nodes=2))
train = jax.jit(tr.train_step)
cons = jax.jit(tr.consensus_step)
losses, rs = [], []
for step in range(10):
    state, m = train(state, data.batch(step))
    losses.append(float(m["loss"]))
    if tr.should_sync(step):
        state, cm = cons(state, data.batch(step, probe=True))
        rs.append(float(cm["r_max"]))
out["losses"] = losses
out["r_norms"] = rs
p0 = jax.tree_util.tree_leaves(state.params)[0]
out["node_divergence"] = float(jnp.abs(p0[0] - p0[1]).max())
out["eta"] = np.asarray(state.penalty.eta).tolist()

# --- compression path compiles and runs ---------------------------------
tr2 = ConsensusTrainer(model, mesh, adamw=AdamWConfig(lr=1e-2),
                       consensus=ConsensusConfig(
                           penalty=PenaltyConfig(scheme="vp", eta0=0.1),
                           topology="ring", local_steps=2,
                           compression="int8"))
st2 = tr2.init_state(jax.random.PRNGKey(1))
st2, _ = jax.jit(tr2.train_step)(st2, data.batch(0))
st2, cm2 = jax.jit(tr2.consensus_step)(st2, data.batch(0, probe=True))
out["int8_r"] = float(cm2["r_max"])

# --- fused Pallas consensus kernel path ----------------------------------
tr3 = ConsensusTrainer(model, mesh, adamw=AdamWConfig(lr=1e-2),
                       consensus=ConsensusConfig(
                           penalty=PenaltyConfig(scheme="ap", eta0=0.1),
                           topology="ring", local_steps=2,
                           use_fused_kernel=False))
st3 = tr3.init_state(jax.random.PRNGKey(2))
st3, cm3 = jax.jit(tr3.consensus_step)(st3, data.batch(0, probe=True))
out["ap_eta_mean"] = float(cm3["eta_mean"])

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def trainer_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_loss_decreases(trainer_results):
    losses = trainer_results["losses"]
    assert losses[-1] < losses[0] * 0.9, losses


def test_consensus_bounds_divergence(trainer_results):
    # H=2 local steps between rounds: replicas drift but stay bounded
    assert trainer_results["node_divergence"] < 1.0


def test_penalties_adapted(trainer_results):
    import numpy as np
    eta = np.asarray(trainer_results["eta"])
    assert eta.shape == (2, 2)
    assert np.all(np.isfinite(eta)) and np.all(eta > 0)


def test_compressed_exchange_runs(trainer_results):
    assert trainer_results["int8_r"] >= 0.0


def test_ap_scheme_bounded_eta(trainer_results):
    # eq.(6): eta in [eta0/2, 2 eta0]
    assert 0.05 - 1e-6 <= trainer_results["ap_eta_mean"] <= 0.2 + 1e-6
