"""Unit tests for the flat-buffer layout table (repro.optim.flatten)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import flatten

from proptest import sweep


def _tree(rng, j=3, dtypes=(np.float32, np.float32, np.float32)):
    return {
        "w": jnp.asarray(rng.normal(size=(j, 5, 37)).astype(dtypes[0])),
        "b": jnp.asarray(rng.normal(size=(j, 11)).astype(dtypes[1])),
        "scalarish": jnp.asarray(rng.normal(size=(j,)).astype(dtypes[2])),
    }


def test_layout_table_is_block_aligned():
    rng = np.random.default_rng(0)
    tree = _tree(rng)
    lay = flatten.FlatLayout.for_tree(tree, block_size=64)
    assert lay.total % lay.block_size == 0
    for lf in lay.leaves:
        assert lf.offset % lay.block_size == 0
        assert lf.padded % lay.block_size == 0
        assert lf.padded >= lf.size > 0
    # block->leaf table covers every block, monotonically
    assert lay.block_leaf.shape == (lay.num_blocks,)
    assert lay.block_leaf[0] == 0
    assert (np.diff(lay.block_leaf) >= 0).all()
    assert lay.block_leaf[-1] == lay.num_leaves - 1


def test_pack_unpack_roundtrip_mixed_dtypes():
    rng = np.random.default_rng(1)
    tree = _tree(rng, dtypes=(np.float32, np.float16, np.float32))
    lay = flatten.FlatLayout.for_tree(tree, block_size=128)
    buf = lay.pack(tree)
    assert buf.shape == (3, lay.total) and buf.dtype == jnp.float32
    back = lay.unpack(buf)
    for k in tree:
        assert back[k].dtype == tree[k].dtype, k
        np.testing.assert_allclose(np.asarray(back[k], np.float32),
                                   np.asarray(tree[k], np.float32),
                                   atol=1e-3 if tree[k].dtype == jnp.float16
                                   else 0)


def test_padding_is_zero_filled():
    rng = np.random.default_rng(2)
    tree = {"a": jnp.asarray(rng.normal(size=(2, 100)).astype(np.float32))}
    lay = flatten.FlatLayout.for_tree(tree, block_size=64)  # pads 100 -> 128
    buf = np.asarray(lay.pack(tree))
    assert lay.total == 128
    assert (buf[:, 100:] == 0).all()


def test_int8_wire_roundtrip_with_inband_scales():
    rng = np.random.default_rng(3)
    tree = _tree(rng)
    lay = flatten.FlatLayout.for_tree(tree, block_size=64)
    buf = lay.pack(tree)
    wire = lay.encode_int8(buf)
    assert wire.dtype == jnp.int8
    assert wire.shape == (3, lay.total + 4 * lay.num_leaves)
    payload, scales = lay.decode_split(wire)
    assert payload.shape == (3, lay.total)
    assert scales.shape == (3, lay.num_leaves)
    # scales survive the int8 bitcast exactly
    np.testing.assert_array_equal(np.asarray(scales),
                                  np.asarray(lay.leaf_scales(buf)))
    # absmax int8: error bounded by scale/2 per element
    deq = payload.astype(jnp.float32) * lay.scale_vector(scales)
    err = np.abs(np.asarray(deq - buf))
    bound = np.asarray(lay.scale_vector(scales)) * 0.5 + 1e-7
    assert (err <= bound).all()
    # float wire passes through decode_split untouched
    p2, s2 = lay.decode_split(buf)
    assert s2 is None and p2 is buf


def test_unpack_with_scales_dequantizes():
    rng = np.random.default_rng(4)
    tree = _tree(rng)
    lay = flatten.FlatLayout.for_tree(tree, block_size=64)
    buf = lay.pack(tree)
    payload, scales = lay.decode_split(lay.encode_int8(buf))
    back = lay.unpack(payload, scales=scales)
    for k in tree:
        amax = float(np.abs(np.asarray(tree[k])).max())
        np.testing.assert_allclose(np.asarray(back[k]), np.asarray(tree[k]),
                                   atol=amax / 127.0 + 1e-6)


def test_auto_block_size_tracks_leaf_scale():
    tiny = {"a": jax.ShapeDtypeStruct((17,), jnp.float32)}
    big = {"a": jax.ShapeDtypeStruct((1 << 20,), jnp.float32)}
    assert flatten.auto_block_size(tiny) == 128
    assert flatten.auto_block_size(big) == 65536


def test_pack_unpack_property_sweep():
    def prop(rng, i):
        j = int(rng.integers(1, 5))
        nleaves = int(rng.integers(1, 6))
        tree = [jnp.asarray(rng.normal(size=(j,) + tuple(
            int(rng.integers(1, 40)) for _ in range(int(rng.integers(0, 3))))
        ).astype(np.float32)) for _ in range(nleaves)]
        bs = int(rng.choice([32, 64, 128]))
        lay = flatten.FlatLayout.for_tree(tree, block_size=bs)
        buf = lay.pack(tree)
        back = lay.unpack(buf)
        for a, b in zip(tree, back):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert lay.total % bs == 0
    sweep(prop, cases=10, seed=17)
