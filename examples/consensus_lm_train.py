"""End-to-end driver: train a (reduced) LM with consensus-ADMM across pods.

Two simulated pods (8 fake CPU devices), ring topology, NAP penalties,
checkpoint + resume, straggler monitoring — the full production loop at toy
scale. On a real fleet only the mesh and config change.

Run:  PYTHONPATH=src python examples/consensus_lm_train.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import sys  # noqa: E402

sys.argv = [sys.argv[0], "--arch", "qwen3-4b", "--reduced",
            "--steps", "20", "--scheme", "nap", "--topology", "ring",
            "--local-steps", "4", "--ckpt-dir", "/tmp/repro_ckpt_example",
            "--ckpt-every", "8"]

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(main())
