"""Quickstart: the paper's adaptive-penalty consensus ADMM in 60 lines.

Solves a distributed least-squares problem on a ring of 8 nodes with each of
the six penalty schedules and prints iterations-to-convergence — the paper's
headline comparison, on a problem small enough to eyeball.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (ConsensusADMM, PenaltyConfig, SCHEMES, build_graph,
                        consensus_error)


def main():
    J, d, n = 8, 5, 20
    rng = np.random.default_rng(0)
    A = rng.normal(size=(J, n, d)).astype(np.float32)
    w_true = rng.normal(size=d).astype(np.float32)
    b = A @ w_true + 0.05 * rng.normal(size=(J, n)).astype(np.float32)
    w_star = np.linalg.lstsq(A.reshape(-1, d), b.reshape(-1), rcond=None)[0]

    def objective(data, theta):
        Ai, bi = data
        return jnp.sum((Ai @ theta["w"] - bi) ** 2)

    data = (jnp.asarray(A), jnp.asarray(b))
    theta0 = {"w": jnp.asarray(rng.normal(size=(J, d)).astype(np.float32))}

    print(f"{'scheme':10s} {'topology':10s} {'iters':>6s} {'max|w-w*|':>10s} "
          f"{'consensus':>10s}")
    for topo in ("complete", "ring"):
        graph = build_graph(topo, J)
        for scheme in SCHEMES:
            engine = ConsensusADMM(
                objective=objective,
                penalty_cfg=PenaltyConfig(scheme=scheme, eta0=1.0),
                graph=graph, inner_steps=30, inner_lr=1.0)
            state = engine.init(theta0)
            state, hist = engine.run(state, data, max_iters=400,
                                     rel_tol=1e-8)
            err = float(np.abs(np.asarray(state.theta["w"]) - w_star).max())
            cons = float(consensus_error(state.theta))
            print(f"{scheme:10s} {topo:10s} {hist['iterations']:6d} "
                  f"{err:10.4f} {cons:10.5f}")


if __name__ == "__main__":
    main()
