"""Dynamic topology: edge gating, shedding, and surviving a node loss.

Three acts on a distributed least-squares problem (12 nodes, expander):

  1. run NAP with the §4 budget scheduler to convergence — same iteration
     count as fixed topology;
  2. keep iterating past convergence — exhausted edges detach one by one
     (watch the active-edge fraction fall) while the solution stays put;
  3. kill a node mid-run — the topology runtime ghosts it, rewires the
     survivors through the spare offsets, and the run just keeps going.

Run:  PYTHONPATH=src python examples/dynamic_topology.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import ConsensusADMM, PenaltyConfig, build_graph
from repro.topology import TopologyConfig


def main():
    J, d, n = 12, 5, 20
    rng = np.random.default_rng(0)
    A = rng.normal(size=(J, n, d)).astype(np.float32)
    w_true = rng.normal(size=d).astype(np.float32)
    b = A @ w_true + 0.05 * rng.normal(size=(J, n)).astype(np.float32)
    w_star = np.linalg.lstsq(A.reshape(-1, d), b.reshape(-1), rcond=None)[0]

    def objective(data, theta):
        Ai, bi = data
        return jnp.sum((Ai @ theta["w"] - bi) ** 2)

    data = (jnp.asarray(A), jnp.asarray(b))
    theta0 = {"w": jnp.asarray(rng.normal(size=(J, d)).astype(np.float32))}
    graph = build_graph("expander", J)

    engine = ConsensusADMM(
        objective=objective,
        penalty_cfg=PenaltyConfig(scheme="nap", eta0=1.0),
        graph=graph, inner_steps=30, inner_lr=1.0,
        topology_cfg=TopologyConfig(scheduler="budget", churn=True))

    # act 1: converge under the paper's §5 criterion
    state = engine.init(theta0)
    state, hist = engine.run(state, data, max_iters=400, rel_tol=1e-3)
    err = float(np.abs(np.asarray(state.theta["w"]) - w_star).max())
    print(f"converged in {hist['iterations']} iterations, "
          f"max|w - w*| = {err:.4f}")

    # act 2: §4 shedding — exhausted edges detach, the iterate holds
    adj_n = int(graph.adj.sum())
    for epoch in range(0, 100, 20):
        for _ in range(20):
            state, m = engine.step(state, data)
        err = float(np.abs(np.asarray(state.theta["w"]) - w_star).max())
        print(f"  +{epoch + 20:3d} epochs: active edges "
              f"{float(m['active_edges']):.2f}, max|w - w*| = {err:.4f}")

    # act 3: lose a node — ghosted, rewired, no restart
    victim = 7
    state = engine.apply_churn(state, victim)
    for _ in range(30):
        state, m = engine.step(state, data)
    alive = np.asarray(state.topo.node_alive)
    w = np.asarray(state.theta["w"])[alive]
    print(f"dropped node {victim}: {int(alive.sum())}/{J} alive, "
          f"survivor consensus spread "
          f"{float(np.abs(w - w.mean(axis=0)).max()):.5f}, "
          f"active edges {float(m['active_edges']):.2f}")


if __name__ == "__main__":
    main()
