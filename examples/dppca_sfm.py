"""Distributed structure-from-motion with D-PPCA + ADMM-NAP (paper §5.2).

Five cameras on a turntable scene reach consensus on the 3D structure
without ever pooling their measurements. Compares the fixed-penalty baseline
against the paper's NAP schedule.

Run:  PYTHONPATH=src python examples/dppca_sfm.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.core import PenaltyConfig, build_graph  # noqa: E402
from repro.ppca import (DPPCA, fit_svd, max_subspace_angle,  # noqa: E402
                        turntable_sfm)


def main():
    sfm = turntable_sfm(num_cameras=5, frames=30, points=90, seed=0)
    x = jnp.asarray(sfm.x_nodes)     # [5 cams, 2F_i rows, N points]
    ref = fit_svd(jnp.asarray(sfm.measurements), 3)
    print(f"scene: {sfm.structure.shape[0]} points, 30 frames, 5 cameras "
          f"(transposed PPCA layout: consensus W == 3D structure)")

    for topo in ("ring", "complete"):
        graph = build_graph(topo, 5)
        for scheme in ("fixed", "nap"):
            eng = DPPCA(latent_dim=3, graph=graph,
                        penalty_cfg=PenaltyConfig(scheme=scheme, eta0=10.0))
            st = eng.init(jax.random.PRNGKey(0), x)
            st, hist = eng.run(st, x, max_iters=400, rel_tol=1e-5,
                               min_iters=10)
            ang = float(max_subspace_angle(st.W, ref.W))
            print(f"  {topo:9s} {scheme:6s}: {hist['iterations']:4d} iters, "
                  f"structure angle vs centralized SVD = {ang:5.2f} deg")


if __name__ == "__main__":
    main()
