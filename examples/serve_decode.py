"""Serving example: batched prefill + greedy decode on a reduced model.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch rwkv6-7b]
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "qwen3-4b"]
    raise SystemExit(main())
