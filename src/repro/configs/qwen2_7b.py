"""qwen2-7b [dense] — GQA kv=4, QKV bias. [arXiv:2407.10671; hf]"""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="qwen2-7b", family="dense", source="arXiv:2407.10671",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab=152064, head_dim=128,
        qkv_bias=True, rope_theta=1_000_000.0,
    ),
    reduced=lambda: dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16),
)
