"""llava-next-mistral-7b [vlm] — Mistral backbone, anyres vision STUB.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="llava-next-mistral-7b", family="vlm",
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=32000, head_dim=128,
        rope_theta=1_000_000.0, frontend="vision_patches",
    ),
    reduced=lambda: dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16),
)
