"""moonshot-v1-16b-a3b [moe] — Moonlight, 64e top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
import dataclasses

from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="moonshot-v1-16b-a3b", family="moe",
        source="hf:moonshotai/Moonlight-16B-A3B",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=163840, head_dim=128,
        moe=MoEConfig(num_experts=64, top_k=6, expert_d_ff=1408),
    ),
    reduced=lambda: dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab=256, head_dim=16,
        moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=96)),
)
