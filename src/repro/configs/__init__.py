"""Architecture configs (one per assigned arch) + shape cells."""
from repro.configs.base import (ARCH_IDS, SHAPES, ArchConfig, MoEConfig,
                                ShapeCell, all_configs, cells, get_config,
                                get_reduced_config)

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "MoEConfig", "ShapeCell",
           "all_configs", "cells", "get_config", "get_reduced_config"]
