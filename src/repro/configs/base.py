"""Architecture + run configuration system.

``ArchConfig`` describes a transformer-family model precisely enough to build
it; one file per assigned architecture lives next to this module and registers
itself via ``register``. ``SHAPES`` are the assigned input-shape cells; a
(arch, shape) pair defines one dry-run/roofline cell.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Literal

Family = Literal["dense", "moe", "audio", "hybrid", "ssm", "vlm"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    # router
    router_jitter: float = 0.0
    capacity_factor: float = 1.25   # EP dispatch buffer headroom


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture. Field semantics follow the assignment table."""

    arch_id: str
    family: Family
    source: str                     # provenance tag from the assignment

    n_layers: int
    d_model: int
    n_heads: int                    # query heads (0 for attn-free)
    n_kv_heads: int                 # GQA KV heads
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 => d_model // n_heads

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0         # 0 => full attention

    # extensions
    moe: MoEConfig | None = None
    ssm_state: int = 0              # hymba-style parallel SSM heads
    rwkv: bool = False              # RWKV6 time-mix instead of attention
    frontend: Literal["none", "audio_frames", "vision_patches"] = "none"

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---------------------------------------------------------------- sizes
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k cell? (SSM / hybrid / windowed)."""
        return self.rwkv or self.ssm_state > 0 or self.sliding_window > 0

    def param_count(self) -> int:
        """Exact parameter count (embedding + L x block + final norm/head)."""
        d, l = self.d_model, self.n_layers
        emb = self.vocab * d
        head = 0 if self.tie_embeddings else self.vocab * d
        blk = 0
        if self.rwkv:
            # time-mix: r,k,v,g,o (d x d) + w lora + ln params (approx exact:
            # receptance/key/value/gate/output + decay lora 2*(d*64)):
            blk += 5 * d * d + 2 * d * 64 + 6 * d
            blk += 2 * d * self.d_ff + d  # channel-mix: k (d,ff), v (ff,d), r
            blk += d * d
        else:
            blk += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                blk += self.q_dim + 2 * self.kv_dim
            if self.ssm_state:  # hymba parallel SSM path
                blk += 2 * d * self.q_dim + self.q_dim * d \
                    + self.q_dim * self.ssm_state * 2 + self.q_dim
            if self.moe is not None:
                e = self.moe
                blk += d * e.num_experts                       # router
                blk += e.num_experts * 3 * d * e.expert_d_ff   # experts
            else:
                blk += 3 * d * self.d_ff                       # swiglu
        blk += 2 * d                                            # 2 norms
        return emb + head + l * blk + d

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        total = self.param_count()
        all_experts = self.n_layers * e.num_experts * 3 * self.d_model \
            * e.expert_d_ff
        active = self.n_layers * e.top_k * 3 * self.d_model * e.expert_d_ff
        return total - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "glm4-9b", "stablelm-3b", "qwen2-7b", "qwen3-4b", "moonshot-v1-16b-a3b",
    "kimi-k2-1t-a32b", "musicgen-large", "hymba-1.5b", "rwkv6-7b",
    "llava-next-mistral-7b",
)

_REGISTRY: dict[str, ArchConfig] = {}
_REDUCED: dict[str, Callable[[], ArchConfig]] = {}


def register(cfg: ArchConfig, reduced: Callable[[], ArchConfig]):
    _REGISTRY[cfg.arch_id] = cfg
    _REDUCED[cfg.arch_id] = reduced
    return cfg


def get_config(arch_id: str) -> ArchConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def get_reduced_config(arch_id: str) -> ArchConfig:
    """Small same-family config for CPU smoke tests."""
    _ensure_loaded()
    return _REDUCED[arch_id]()


def all_configs() -> dict[str, ArchConfig]:
    _ensure_loaded()
    return dict(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    for arch in ARCH_IDS:
        importlib.import_module(
            f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")
    _LOADED = True


def cells(include_skipped: bool = True):
    """Yield every (arch, shape) assignment cell with its skip status."""
    _ensure_loaded()
    for arch_id in ARCH_IDS:
        cfg = _REGISTRY[arch_id]
        for shape in SHAPES.values():
            skip = (shape.name == "long_500k" and not cfg.sub_quadratic)
            yield cfg, shape, skip
