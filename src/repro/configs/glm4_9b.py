"""glm4-9b [dense] — RoPE, GQA kv=2. [hf:THUDM/glm-4-9b; hf]"""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="glm4-9b", family="dense", source="hf:THUDM/glm-4-9b",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab=151552, head_dim=128,
        qkv_bias=True, rope_theta=10_000.0,
    ),
    reduced=lambda: dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16),
)
