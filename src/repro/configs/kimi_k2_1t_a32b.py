"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384e top-8 (paper-table).
[arXiv:2501.kimi2; unverified]"""
import dataclasses

from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="kimi-k2-1t-a32b", family="moe", source="arXiv:2501.kimi2",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=2048, vocab=163840, head_dim=112,
        moe=MoEConfig(num_experts=384, top_k=8, expert_d_ff=2048),
    ),
    reduced=lambda: dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=256, head_dim=16,
        moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=96)),
)
