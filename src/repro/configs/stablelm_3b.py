"""stablelm-3b [dense] — MHA (kv=32). [hf:stabilityai/stablelm-2-1_6b; unverified]"""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="stablelm-3b", family="dense",
        source="hf:stabilityai/stablelm-2-1_6b",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=6912, vocab=50304, head_dim=80,
    ),
    reduced=lambda: dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, head_dim=16),
)
