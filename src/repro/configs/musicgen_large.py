"""musicgen-large [audio] — decoder-only over EnCodec tokens; frontend STUB.
[arXiv:2306.05284; hf]"""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="musicgen-large", family="audio", source="arXiv:2306.05284",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=2048, head_dim=64,
        frontend="audio_frames",
    ),
    reduced=lambda: dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, head_dim=16),
)
