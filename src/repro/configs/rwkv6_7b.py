"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="rwkv6-7b", family="ssm", source="arXiv:2404.05892",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
        d_ff=14336, vocab=65536, head_dim=64,
        rwkv=True,
    ),
    reduced=lambda: dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, head_dim=16),
)
