"""hymba-1.5b [hybrid] — parallel attention + mamba heads, ssm_state=16.
[arXiv:2411.13676; hf]

Hymba runs attention and SSM heads in parallel within each block and uses
sliding-window attention in most layers => sub-quadratic, runs long_500k.
(Meta-tokens and the few global-attention layers are omitted; DESIGN.md §4.)
"""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = register(
    ArchConfig(
        arch_id="hymba-1.5b", family="hybrid", source="arXiv:2411.13676",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
        d_ff=5504, vocab=32001, head_dim=64,
        ssm_state=16, sliding_window=1024,
    ),
    reduced=lambda: dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, head_dim=16, ssm_state=8, sliding_window=32),
)
