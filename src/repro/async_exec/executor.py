"""Host-side driver for bounded-staleness consensus rounds.

``AsyncExecutor`` glues the three traced/host pieces together:

  * the trainer's ``consensus_step_async`` (the traced round: wire ledger,
    staleness clocks, masked fused kernel with zero-kick absorption),
  * the ``RoundClock`` event model (which nodes advance this fleet tick,
    which payloads landed — in a real deployment these come from the
    double buffer's DMA completion bits instead),
  * wall-clock accounting (modeled async elapsed vs the synchronous
    barrier equivalent for the same amount of consensus progress).

The executor is deliberately thin: all numerics live in the trainer, all
timing in the clock. It exists so the launcher and the benchmarks drive
async training through one object with one contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.async_exec.clock import RoundClock
from repro.obs.trace import host_span_factory


class AsyncExecutor:
    """Drives a ``ConsensusTrainer`` with bounded-staleness rounds.

    Args:
      trainer: a ``repro.optim.ConsensusTrainer`` built with
        ``ConsensusConfig(async_exec=AsyncConfig(...))``.
      clock: a ``RoundClock``; None builds a homogeneous fleet (every
        payload always arrives — the no-straggler fast path).
    """

    def __init__(self, trainer, clock: RoundClock | None = None):
        if trainer.async_cfg is None:
            raise ValueError("trainer was built without ConsensusConfig."
                             "async_exec — nothing to execute")
        self.trainer = trainer
        self.cfg = trainer.async_cfg
        if clock is None:
            clock = RoundClock(
                compute_s=np.ones(trainer.num_nodes),
                wire_s=0.0, offsets=tuple(trainer.offsets))
        if clock.num_nodes != trainer.num_nodes:
            raise ValueError(f"clock models {clock.num_nodes} nodes, "
                             f"trainer has {trainer.num_nodes}")
        self.clock = clock
        self._cons = trainer.jit_async_step_fns()
        self._hspan = host_span_factory(
            trainer.obs_on and trainer.obs_cfg.with_spans)

    # ------------------------------------------------------------ state ----
    def init_state(self, key: jax.Array):
        return self.trainer.init_state(key)

    # ------------------------------------------------------------ steps ----
    def consensus_round(self, state, probe_batch):
        """One fleet tick: clock -> (arrivals, advance) -> traced round.

        With ``max_staleness=0`` the executor waits for everything — every
        payload is marked arrived and every node advances, which is the
        synchronous round bit-for-bit.
        """
        j = self.trainer.num_nodes
        deg = max(len(self.trainer.offsets), 1)
        if self.cfg.max_staleness == 0:
            arrivals = jnp.ones((deg, j), bool)
            advance = None
            self.clock.time_s += self.clock.sync_round_s
            self.clock.ticks += 1
        else:
            arr_np, adv_np = self.clock.tick()
            arrivals = jnp.asarray(arr_np)
            advance = jnp.asarray(adv_np)
        with self._hspan("round/async"):
            state, metrics = self._cons(state, probe_batch, arrivals, advance)
        return state, metrics

    # ------------------------------------------------------- accounting ----
    @property
    def async_elapsed_s(self) -> float:
        """Modeled wall-clock spent so far (clock conventions).

        There is deliberately no "sync equivalent" counterpart: an async
        fleet tick advances only the nodes whose rounds completed, so
        tick counts and synchronous round counts are NOT interchangeable
        — compare executors by progress-to-target, the way
        ``benchmarks/async_staleness.py`` does.
        """
        return float(self.clock.time_s)

    def summary(self) -> dict:
        c = self.clock
        rounds = np.asarray(c.rounds_done, dtype=np.int64)
        # per-node lag behind the fleet's front-runner, in consensus
        # rounds — the straggler scorer (obs.health) reads the percentiles
        lag = (rounds.max() - rounds) if rounds.size else rounds
        return {
            "ticks": int(c.ticks),
            "rounds_done": rounds.tolist(),
            "round_lag": lag.tolist(),
            "lag_p50": float(np.percentile(lag, 50)) if lag.size else 0.0,
            "lag_p90": float(np.percentile(lag, 90)) if lag.size else 0.0,
            "lag_p100": float(lag.max()) if lag.size else 0.0,
            "async_elapsed_s": round(self.async_elapsed_s, 6),
            "sync_round_s": round(c.sync_round_s, 6),
            "tick_s": round(c.tick_s, 6),
            "max_staleness": self.cfg.max_staleness,
        }

    def export_timeline(self, path: str) -> str:
        """Write the clock's modeled timeline as a Chrome/Perfetto trace.

        Per-node compute and wire tracks reconstructed from the clock's
        event model (``repro.obs.export``) — load the JSON in
        https://ui.perfetto.dev next to a measured ``--profile-rounds``
        trace to compare modeled and actual compute/wire overlap.
        """
        from repro.obs.export import write_roundclock_trace
        return write_roundclock_trace(self.clock, path)
