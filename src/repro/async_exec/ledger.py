"""In-flight wire state for the bounded-staleness consensus executor.

The synchronous engine's exchange is fire-and-consume: every graph offset's
collective-permute must land before the fused round runs. The async
executor instead keeps a **wire ledger** — a double buffer of the last
payload successfully consumed per directed edge — so round k's prox/dual
work can proceed on whatever has arrived while round k's permutes are still
in flight. The buffer discipline is most-recent-wins (each sender
overwrites its slot with its latest parameters; a receiver that missed a
round reads the newest complete slot, never a queue of old ones), which is
exactly what a double-buffered RDMA mailbox implements on real hardware.

The ledger stores the RAW wire rows (`[deg, J, W]`, the same bytes the
permute moves — quantized payloads keep their bitcast scale bytes
in-band), so holding a stale payload costs zero recompute: the wire codec
(``repro.wire``, which also sizes W) peels payload and scales at
consumption time, same as the fresh path.

Staleness accounting does NOT live here: the per-edge clocks are
``topology.TopologyState.age`` (the topology runtime is the single owner of
per-edge state — gates, epochs, clocks, pending kicks). The ledger is only
the payload buffer those clocks describe.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Knobs for the bounded-staleness executor.

    Attributes:
      max_staleness: how many consensus rounds old a consumed payload may
        be. 0 = wait for everything: the async step degenerates to the
        synchronous round (pinned bit-identical by test). N >= 1 lets a
        node proceed on payloads up to N rounds old; an edge whose payload
        ages past N is temporarily gated (zero math, zero-kick absorbed)
        until a fresh payload lands.
      stale_gamma: staleness damping strength — a stale edge's applied
        penalty is eta / (1 + gamma * age) (``core.penalty
        .staleness_damping``), so duals built against old neighbor
        estimates do not over-penalize. 0 disables damping.
    """

    max_staleness: int = 1
    stale_gamma: float = 0.5

    def __post_init__(self):
        if self.max_staleness < 0:
            raise ValueError(f"max_staleness {self.max_staleness} < 0")
        if self.stale_gamma < 0.0:
            raise ValueError(f"stale_gamma {self.stale_gamma} < 0")


class WireLedger(NamedTuple):
    """Traced double-buffer of last-consumed wire rows.

    ``w_prev`` rides along: the symmetrized, staleness-damped penalty
    weight each edge actually applied LAST round. When an edge ages past
    the bound, its zero-kick absorption must remove exactly the force it
    was applying — the penalty state has already advanced one update by
    then, so the applied weight is snapshotted here instead of recomputed.
    """

    wires: jax.Array   # [deg, J, W] — raw wire rows, one per graph offset
    round: jax.Array   # []  int32  — async rounds completed
    w_prev: jax.Array  # [J, J] f32 — weights applied last round


def _codec_for(layout, compression: str, slayout=None):
    from repro import wire
    return wire.get_codec(compression, layout, slayout)


def wire_width(layout, compression: str, slayout=None) -> int:
    """Elements per wire row (quantized payloads carry their scale bytes).

    Delegates to the wire codec (``repro.wire``): ``compression`` is any
    codec name or the legacy ``"none"`` spelling. With ``slayout`` (a
    ``flatten.ShardedLayout``) the row is the SHARDED wire format —
    per-shard slabs each carrying their own scale bytes, so a device's
    ledger slab holds exactly the bytes its shard decodes (staleness
    absorption reads only local bytes).
    """
    return _codec_for(layout, compression, slayout).wire_width


def wire_row_dtype(layout, compression: str):
    return _codec_for(layout, compression).wire_dtype


def init_wire_ledger(layout, deg: int, num_nodes: int,
                     compression: str = "none", slayout=None,
                     codec=None) -> WireLedger:
    """Zero-filled ledger; the executor guarantees the first read of every
    edge is fresh (the clock marks a node's initial parameters as a landed
    round -1 send), so the zeros are never consumed.

    Rows are sized and typed by the wire codec: pass ``codec`` (a
    ``repro.wire.WireCodec``, what the trainer does) or let the legacy
    ``compression``/``slayout`` pair resolve one.
    """
    if codec is None:
        codec = _codec_for(layout, compression, slayout)
    return WireLedger(
        wires=jnp.zeros((max(deg, 1), num_nodes, codec.wire_width),
                        codec.wire_dtype),
        round=jnp.zeros((), jnp.int32),
        w_prev=jnp.zeros((num_nodes, num_nodes), jnp.float32))
