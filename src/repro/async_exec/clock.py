"""Discrete-event round clock for the bounded-staleness executor.

The numerics of an async round are exact (stale payloads really feed the
fused update); what a single-process simulation cannot produce is the
*wall-clock* of a fleet with heterogeneous node speeds. ``RoundClock``
supplies it: a deterministic event model of J nodes, each taking
``compute_s[i]`` seconds per consensus round (H local steps + the fused
update) and ``wire_s`` seconds for a payload to cross the DCN.

One ``tick()`` advances global time by the fastest node's round time and
reports, for that fleet tick,

  * ``advance`` [J]  — which nodes completed a round in this tick (a 2x
    slow node advances every other tick);
  * ``arrivals`` [deg, J] — which directed edges' payloads landed fresh
    since the receiver's last read (most-recent-wins slots: a sender's
    newest landed payload supersedes older unread ones).

Timing model (stated, not hidden):

  * async — permutes are double-buffered behind compute, so a node's round
    time is its compute time alone; a payload sent at a round's end lands
    ``wire_s`` later. Fleet wall-clock = ticks x min(compute_s): nobody
    barriers, the slow node just lands fewer sends.
  * sync — every round barriers on the slowest node AND serializes the
    exchange behind compute: ``sync_round_s = max(compute_s) + wire_s``.

These are the same modeling conventions as ``launch.dryrun
.fused_round_roofline`` (analytic wire/HBM accounting next to measured
numerics); ``benchmarks/async_staleness.py`` derives ``wire_s`` from
``FlatLayout.wire_bytes`` over a stated DCN bandwidth.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class RoundClock:
    """Event clock for one fleet. Mutable: ``tick()`` advances it."""

    compute_s: np.ndarray          # [J] per-node seconds per round
    wire_s: float                  # DCN latency of one payload
    offsets: tuple                 # the engine's compiled offset schedule

    def __post_init__(self):
        self.compute_s = np.asarray(self.compute_s, dtype=float)
        j = self.num_nodes
        if (self.compute_s <= 0).any():
            raise ValueError("compute_s must be positive")
        self.time_s = 0.0
        self.ticks = 0
        self.rounds_done = np.zeros(j, dtype=int)
        self.next_done = self.compute_s.copy()      # first completion times
        # last send id consumed per (receiver, sender); the initial params
        # count as send id 0, landed at t=0, unread (-1) => first read of
        # every edge is fresh, so the zero-filled ledger is never consumed
        self.last_read = np.full((j, j), -1, dtype=int)

    @property
    def num_nodes(self) -> int:
        return int(self.compute_s.shape[0])

    @property
    def tick_s(self) -> float:
        """Async fleet tick: the fastest node's round time."""
        return float(self.compute_s.min())

    @property
    def sync_round_s(self) -> float:
        """Synchronous round: barrier on the slowest node + the exchange."""
        return float(self.compute_s.max()) + float(self.wire_s)

    def _latest_landed(self, t: float) -> np.ndarray:
        """[J] newest send id of each node landed at receivers by time t.

        Send id k (the node's k-th completed round) lands at
        ``k * compute_s + wire_s``; id 0 (initial params) lands at 0.
        """
        k = np.floor((t - self.wire_s) / self.compute_s).astype(int)
        return np.maximum(k, 0)

    def tick(self) -> tuple[np.ndarray, np.ndarray]:
        """Advance one fleet tick -> (arrivals [deg, J], advance [J])."""
        j = self.num_nodes
        self.time_s += self.tick_s
        self.ticks += 1
        eps = 1e-9 * max(self.tick_s, 1.0)
        advance = self.next_done <= self.time_s + eps
        self.rounds_done[advance] += 1
        self.next_done[advance] += self.compute_s[advance]

        landed = self._latest_landed(self.time_s)
        arrivals = np.zeros((max(len(self.offsets), 1), j), dtype=bool)
        idx = np.arange(j)
        for d, off in enumerate(self.offsets):
            senders = (idx + off) % j
            fresh = advance & (landed[senders] > self.last_read[idx, senders])
            arrivals[d] = fresh
            self.last_read[idx[fresh], senders[fresh]] = landed[
                senders[fresh]]
        return arrivals, advance


def straggler_compute(num_nodes: int, *, base_s: float = 1.0,
                      victim: int = 0, factor: float = 2.0) -> np.ndarray:
    """[J] per-node round times with one slow node (the benchmark's 2x)."""
    c = np.full(num_nodes, base_s, dtype=float)
    c[victim] = base_s * factor
    return c
