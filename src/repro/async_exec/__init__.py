"""Bounded-staleness async consensus executor.

See ``docs/async_executor.md`` for the staleness model, its invariants and
the knobs. The traced round itself lives on the trainer
(``repro.optim.ConsensusTrainer.consensus_step_async``); this package owns
the wire ledger, the event clock and the host driver.
"""
from repro.async_exec.clock import RoundClock, straggler_compute
from repro.async_exec.executor import AsyncExecutor
from repro.async_exec.ledger import (AsyncConfig, WireLedger,
                                     init_wire_ledger, wire_row_dtype,
                                     wire_width)

__all__ = [
    "AsyncConfig", "AsyncExecutor", "RoundClock", "WireLedger",
    "init_wire_ledger", "straggler_compute", "wire_row_dtype", "wire_width",
]
