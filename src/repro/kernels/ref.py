"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int = 0) -> jax.Array:
    """q,k,v: [B, H, S, hd] (head-major layout the kernel uses)."""
    b, h, s, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def rwkv6_scan_ref(r, k, v, log_w, u, s0):
    """WKV6 recurrence oracle.

    r,k,v: [B, H, T, hd]; log_w: [B, H, T, hd] (log decay, <= 0);
    u: [H, hd]; s0: [B, H, hd, hd] (key x value).
    Returns (y [B, H, T, hd], s_final).
    """
    w = jnp.exp(log_w.astype(jnp.float32))

    def step(s, inp):
        rt, kt, vt, wt = inp   # [B, H, hd]
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", rt.astype(jnp.float32),
                       s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(t, 2, 0).astype(jnp.float32)
               for t in (r, k, v, w))
    s_last, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 2).astype(r.dtype), s_last


def consensus_round_ref(theta, lam, bar_prev, wires, scales, e_sym,
                        alpha, eta_sum, eta_node, *,
                        block_leaf, block_size: int,
                        bar_w=None, inv_deg=None, kick_w=None,
                        scales_per_block: bool = False):
    """Whole-round flat-buffer oracle (see consensus_update.consensus_round).

    Reductions are evaluated blockwise in the kernel's order so the fused
    and reference paths agree to float32 round-off, not just statistically.
    ``bar_w``/``inv_deg`` mirror the kernel's dynamic-topology edge gating
    (both None = the ungated PR 1 math); ``kick_w`` mirrors its zero-kick
    dual absorption for newly-gated edges; ``scales_per_block`` mirrors the
    fp8 codecs' per-block dequant granularity (``scales`` then carries
    [deg, J, num_blocks] rows on the layout's block grid).
    """
    j, total = theta.shape
    deg = wires.shape[0]
    if scales_per_block:
        srows = scales.astype(jnp.float32)
    else:
        bl = jnp.asarray(block_leaf, jnp.int32)
        srows = scales.astype(jnp.float32)[..., bl]
    scale_vec = jnp.repeat(srows, block_size,
                           axis=-1, total_repeat_length=total)
    x = wires.astype(jnp.float32) * scale_vec          # [deg, J, total]
    e = e_sym.astype(jnp.float32)[..., None]
    nbr_w = (e * x).sum(axis=0)
    if bar_w is not None:
        assert inv_deg is not None, "bar_w and inv_deg travel together"
        w = bar_w.astype(jnp.float32)[..., None]       # [deg, J, 1]
        bar = (w * x).sum(axis=0) \
            * jnp.asarray(inv_deg, jnp.float32)[:, None]
    else:
        bar = x.sum(axis=0) * (1.0 / deg)
    eta_sum = jnp.asarray(eta_sum, jnp.float32)
    nbr = nbr_w / jnp.maximum(eta_sum, 1e-12)[:, None]
    theta32 = theta.astype(jnp.float32)
    lam32 = lam.astype(jnp.float32)
    alpha = jnp.asarray(alpha, jnp.float32)[:, None]
    theta_new = theta32 - alpha * (2.0 * lam32
                                   + eta_sum[:, None] * (theta32 - nbr))
    lam_new = lam32 + 0.5 * eta_sum[:, None] * (theta_new - nbr)
    if kick_w is not None:
        assert bar_w is not None, "kick_w needs the masked variant"
        k = kick_w.astype(jnp.float32)                 # [deg, J]
        kick_x = (k[..., None] * x).sum(axis=0)
        lam_new = lam_new + 0.5 * (k.sum(axis=0)[:, None] * theta32 - kick_x)

    def blocksum(v):
        return v.reshape(j, -1, block_size).sum(axis=-1).sum(axis=-1)

    r_sq = blocksum((theta_new - bar) ** 2)
    dbar = bar - bar_prev.astype(jnp.float32)
    s_sq = (jnp.asarray(eta_node, jnp.float32) ** 2) * blocksum(dbar * dbar)
    return (theta_new.astype(theta.dtype), lam_new.astype(lam.dtype),
            bar, r_sq, s_sq)


def consensus_update_ref(theta, lam, nbr_avg, theta_bar, theta_bar_prev,
                         *, eta_sum, eta_node, step_size):
    """Fused consensus round oracle (flat vectors).

    theta_new = theta - step * (2 lam + eta_sum (theta - nbr_avg))
    lam_new   = lam + 0.5 eta_sum (theta_new - nbr_avg)
    r_sq      = sum (theta_new - theta_bar)^2
    s_sq      = eta_node^2 sum (theta_bar - theta_bar_prev)^2
    """
    theta32 = theta.astype(jnp.float32)
    lam32 = lam.astype(jnp.float32)
    nbr32 = nbr_avg.astype(jnp.float32)
    theta_new = theta32 - step_size * (2.0 * lam32
                                       + eta_sum * (theta32 - nbr32))
    lam_new = lam32 + 0.5 * eta_sum * (theta_new - nbr32)
    r_sq = jnp.sum((theta_new - theta_bar.astype(jnp.float32)) ** 2)
    diff = theta_bar.astype(jnp.float32) - theta_bar_prev.astype(jnp.float32)
    s_sq = (eta_node ** 2) * jnp.sum(diff ** 2)
    return (theta_new.astype(theta.dtype), lam_new.astype(lam.dtype),
            r_sq, s_sq)
