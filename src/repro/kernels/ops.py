"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True in this CPU container (the kernels TARGET TPU;
interpret mode executes the kernel body in Python for validation). On real
TPU runtimes set ``repro.kernels.ops.INTERPRET = False`` (or pass through).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import consensus_update as _cu
from repro.kernels import flash_attention as _fa
from repro.kernels import rwkv6_scan as _rw

INTERPRET = True


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """Model-layout wrapper: q [B,S,H,hd], k/v [B,S,K,hd] -> [B,S,H,hd]."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=INTERPRET)
    return jnp.swapaxes(out, 1, 2)


def flash_attention_hmajor(q, k, v, **kw):
    """Head-major passthrough: q [B,H,S,hd]."""
    return _fa.flash_attention(q, k, v, interpret=INTERPRET, **kw)


def rwkv6_scan(r, k, v, w, u, s0, *, chunk: int = 32):
    """Model-layout wrapper: r/k/v/w [B,S,H,hd] (w = decay in (0,1))."""
    rt, kt, vt = (jnp.swapaxes(t, 1, 2) for t in (r, k, v))
    log_w = jnp.log(jnp.maximum(jnp.swapaxes(w, 1, 2), 1e-38))
    y, s = _rw.rwkv6_scan(rt, kt, vt, log_w, u, s0, chunk=chunk,
                          interpret=INTERPRET)
    return jnp.swapaxes(y, 1, 2), s


def consensus_update(theta, lam, nbr_avg, theta_bar, theta_bar_prev, *,
                     eta_sum, eta_node, step_size, block_size: int = 65536):
    return _cu.consensus_update(theta, lam, nbr_avg, theta_bar,
                                theta_bar_prev, eta_sum=eta_sum,
                                eta_node=eta_node, step_size=step_size,
                                block_size=block_size, interpret=INTERPRET)
