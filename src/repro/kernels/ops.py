"""Jit'd public wrappers for the Pallas kernels.

``interpret`` is auto-detected: compiled Mosaic on TPU backends, Pallas
interpret mode (kernel body evaluated with plain HLO ops — jit/shard_map
traceable) everywhere else. Override order:

  1. ``repro.kernels.ops.INTERPRET = True/False`` (module attribute),
  2. ``REPRO_PALLAS_INTERPRET=1/0`` in the environment,
  3. ``jax.default_backend() != "tpu"``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import consensus_update as _cu
from repro.kernels import flash_attention as _fa
from repro.kernels import rwkv6_scan as _rw

INTERPRET: bool | None = None    # None => auto (env var, then backend probe)

_ENV_VAR = "REPRO_PALLAS_INTERPRET"
_TRUTHY = ("1", "true", "yes", "on")


def interpret_mode() -> bool:
    """Resolve whether Pallas kernels should run in interpret mode."""
    if INTERPRET is not None:
        return bool(INTERPRET)
    env = os.environ.get(_ENV_VAR, "").strip().lower()
    if env:
        return env in _TRUTHY
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """Model-layout wrapper: q [B,S,H,hd], k/v [B,S,K,hd] -> [B,S,H,hd]."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret_mode())
    return jnp.swapaxes(out, 1, 2)


def flash_attention_hmajor(q, k, v, **kw):
    """Head-major passthrough: q [B,H,S,hd]."""
    return _fa.flash_attention(q, k, v, interpret=interpret_mode(), **kw)


def rwkv6_scan(r, k, v, w, u, s0, *, chunk: int = 32):
    """Model-layout wrapper: r/k/v/w [B,S,H,hd] (w = decay in (0,1))."""
    rt, kt, vt = (jnp.swapaxes(t, 1, 2) for t in (r, k, v))
    log_w = jnp.log(jnp.maximum(jnp.swapaxes(w, 1, 2), 1e-38))
    y, s = _rw.rwkv6_scan(rt, kt, vt, log_w, u, s0, chunk=chunk,
                          interpret=interpret_mode())
    return jnp.swapaxes(y, 1, 2), s


def consensus_update(theta, lam, nbr_avg, theta_bar, theta_bar_prev, *,
                     eta_sum, eta_node, step_size, block_size: int = 65536):
    return _cu.consensus_update(theta, lam, nbr_avg, theta_bar,
                                theta_bar_prev, eta_sum=eta_sum,
                                eta_node=eta_node, step_size=step_size,
                                block_size=block_size, interpret=interpret_mode())


def consensus_round(theta, lam, bar_prev, wires, scales, e_sym,
                    alpha, eta_sum, eta_node, *, block_leaf, block_size,
                    whole_rows: bool | None = None,
                    bar_w=None, inv_deg=None, kick_w=None,
                    block_leaf_arr=None, scales_per_block: bool = False):
    """Whole-round fused flat-buffer kernel (see consensus_update module).

    ``bar_w``/``inv_deg`` select the edge-gated dynamic-topology variant;
    ``kick_w`` additionally compiles the zero-kick dual absorption.
    ``block_leaf_arr`` (traced) replaces the static ``block_leaf`` tuple on
    the sharded engine path (per-device slab tables).
    ``scales_per_block`` selects the per-BLOCK dequant granularity of the
    fp8 wire codecs (``repro.wire``) instead of the per-leaf table lookup.
    """
    return _cu.consensus_round(theta, lam, bar_prev, wires, scales, e_sym,
                               alpha, eta_sum, eta_node,
                               block_leaf=(None if block_leaf is None
                                           else tuple(block_leaf)),
                               block_size=block_size,
                               interpret=interpret_mode(),
                               whole_rows=whole_rows,
                               bar_w=bar_w, inv_deg=inv_deg, kick_w=kick_w,
                               block_leaf_arr=block_leaf_arr,
                               scales_per_block=scales_per_block)
