"""Pallas flash attention (causal / sliding-window, GQA-aware).

TPU-native tiling: queries blocked [block_q, head_dim] in VMEM, K/V streamed
in [block_k, head_dim] tiles along the innermost (sequential) grid axis with
the online-softmax accumulators (m, l, acc) carried in VMEM scratch. MXU work
is the two [block_q, block_k] x [block_k, head_dim] matmuls per tile; fully
masked tiles (beyond the causal diagonal or the sliding window) are skipped
with ``pl.when``.

Layout: [B, H, S, hd] head-major. GQA is expressed in the K/V index_map
(query head h reads KV head h // n_rep) so KV tiles are never materialized
per query head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_k: int, causal: bool, window: int,
            num_k_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # tile visibility: skip tiles fully above the causal diagonal or fully
    # left of the sliding window
    pred = ki >= 0
    if causal:
        pred &= k_start <= q_start + block_q - 1
    if window > 0:
        pred &= k_start + block_k - 1 > q_start - window

    @pl.when(pred)
    def _compute():
        q = q_ref[...].astype(jnp.float32)              # [bq, hd]
        k = k_ref[...].astype(jnp.float32)              # [bk, hd]
        v = v_ref[...].astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # [bq]
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_cur

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: [B, H, S, hd]; k, v: [B, K, S, hd] with H = K * n_rep."""
    b, h, s, hd = q.shape
    kheads = k.shape[1]
    assert h % kheads == 0, (h, kheads)
    n_rep = h // kheads
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    num_k_blocks = s // block_k

    grid = (b, h, s // block_q, num_k_blocks)
    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, causal=causal,
        window=window, num_k_blocks=num_k_blocks)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, hd),
                         lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((None, None, block_k, hd),
                         lambda bb, hh, qi, ki, n_rep=n_rep:
                         (bb, hh // n_rep, ki, 0)),
            pl.BlockSpec((None, None, block_k, hd),
                         lambda bb, hh, qi, ki, n_rep=n_rep:
                         (bb, hh // n_rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, hd),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # m: running max
            pltpu.VMEM((block_q,), jnp.float32),       # l: running sum
            pltpu.VMEM((block_q, hd), jnp.float32),    # acc: running output
        ],
        interpret=interpret,
    )(q, k, v)
