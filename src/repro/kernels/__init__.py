"""Pallas TPU kernels (validated in interpret mode on CPU) + oracles."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
