"""Pallas fused consensus-round update — the ADMM hot loop in one HBM pass.

One ADMM consensus round touches every parameter ~6 times when written
naively (prox pull, dual update, two residual reductions, two neighbor
means). The math is all elementwise over the flattened parameter vector, so
it is purely memory-bound: fusing it into a single kernel takes the round
from ~6 HBM passes to 1 read + 2 writes.

Per block of the flat parameter vector:
    theta_new = theta - step (2 lam + eta_sum (theta - nbr_avg))
    lam_new   = lam + 0.5 eta_sum (theta_new - nbr_avg)
    r_sq     += |theta_new - theta_bar|^2          (per-block partials)
    s_sq     += eta_node^2 |theta_bar - theta_bar_prev|^2
Scalars (eta_sum, eta_node, step) ride in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(scalars_ref, theta_ref, lam_ref, nbr_ref, bar_ref, barp_ref,
            theta_out, lam_out, rsq_out, ssq_out):
    eta_sum = scalars_ref[0]
    eta_node = scalars_ref[1]
    step = scalars_ref[2]
    theta = theta_ref[...].astype(jnp.float32)
    lam = lam_ref[...].astype(jnp.float32)
    nbr = nbr_ref[...].astype(jnp.float32)
    bar = bar_ref[...].astype(jnp.float32)
    barp = barp_ref[...].astype(jnp.float32)

    theta_new = theta - step * (2.0 * lam + eta_sum * (theta - nbr))
    lam_new = lam + 0.5 * eta_sum * (theta_new - nbr)
    theta_out[...] = theta_new.astype(theta_out.dtype)
    lam_out[...] = lam_new.astype(lam_out.dtype)
    rsq_out[0] = jnp.sum((theta_new - bar) ** 2)
    dbar = bar - barp
    ssq_out[0] = (eta_node * eta_node) * jnp.sum(dbar * dbar)


@functools.partial(jax.jit,
                   static_argnames=("block_size", "interpret"))
def consensus_update(theta, lam, nbr_avg, theta_bar, theta_bar_prev, *,
                     eta_sum, eta_node, step_size,
                     block_size: int = 65536, interpret: bool = True):
    """All tensor args are flat [N] vectors (pad to block multiple upstream).

    Returns (theta_new [N], lam_new [N], r_sq scalar, s_sq scalar).
    """
    (n,) = theta.shape
    block_size = min(block_size, n)
    assert n % block_size == 0, (n, block_size)
    grid = (n // block_size,)
    scalars = jnp.stack([jnp.asarray(eta_sum, jnp.float32),
                         jnp.asarray(eta_node, jnp.float32),
                         jnp.asarray(step_size, jnp.float32)])

    vec = pl.BlockSpec((block_size,), lambda i: (i,))
    theta_new, lam_new, rsq, ssq = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            vec, vec, vec, vec, vec,
        ],
        out_specs=[
            vec, vec,
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), theta.dtype),
            jax.ShapeDtypeStruct((n,), lam.dtype),
            jax.ShapeDtypeStruct(grid, jnp.float32),
            jax.ShapeDtypeStruct(grid, jnp.float32),
        ],
        interpret=interpret,
    )(scalars, theta, lam, nbr_avg, theta_bar, theta_bar_prev)
    return theta_new, lam_new, rsq.sum(), ssq.sum()
