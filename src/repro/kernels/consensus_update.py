"""Pallas fused consensus-round kernels — the ADMM hot loop in one HBM pass.

One ADMM consensus round touches every parameter ~6 times when written
naively (prox pull, dual update, two residual reductions, two neighbor
means) plus one more full pass to dequantize an int8 wire payload. The math
is all elementwise over the flattened parameter vector, so it is purely
memory-bound: fusing it into a single kernel takes the round from ~7 HBM
passes to one read per operand + one write per result.

Two entry points:

  * ``consensus_update`` — the original per-vector kernel (prox pull + dual
    update + both residual partials; neighbor means precomputed upstream).
    Kept as the simple building block and oracle target.
  * ``consensus_round`` — the flat-buffer engine kernel: takes the raw
    *rolled wire payloads* for every graph offset (int8/fp8 or float) and
    fuses dequantization, both neighbor means, prox pull, dual update and
    both residual reductions. Per-node scalars (alpha, eta_sum, eta_node),
    the per-offset edge weights and the per-offset dequant scales ride in
    SMEM. Scale granularity is codec-parameterized
    (``repro.wire.DequantSpec``): per-(node, leaf) scales resolve through
    the block->leaf table (the int8 wire), per-(node, BLOCK) scales (the
    fp8 wires) index by the block's own program id — no table lookup, and
    the scale rows shard with the slabs on the sharded engine.

Per block of the flat parameter vector (``consensus_round``):
    nbr_w     = sum_d e_sym[d] * dequant(wire[d])
    bar       = sum_d dequant(wire[d]) / deg
    nbr_avg   = nbr_w / max(eta_sum, eps)
    theta_new = theta - alpha (2 lam + eta_sum (theta - nbr_avg))
    lam_new   = lam + 0.5 eta_sum (theta_new - nbr_avg)
    r_sq     += |theta_new - bar|^2                      (per-block partials)
    s_sq     += eta_node^2 |bar - bar_prev|^2

Dynamic topology (``bar_w``/``inv_deg`` supplied — see ``repro.topology``):
the traced per-(offset, node) edge gate ``bar_w`` weights the neighbor-mean
accumulation and the per-node ``inv_deg`` (1 / active degree) replaces the
static 1/deg, so a gated edge contributes exactly zero math. The ungated
path is byte-for-byte the PR 1 kernel — ``scheduler="static"`` stays
bit-identical by construction.

Zero-kick gating (``kick_w`` supplied, masked variants only): when the
scheduler gates an edge, its final consensus force ``w_ij (theta_i -
theta_j)`` is absorbed into the dual — one extra dual-ascent step
restricted to the newly-gated edges — so removing the edge leaves every
node's augmented stationarity unchanged at the current iterate.
``kick_w[d, i]`` is the symmetrized penalty weight of the newly-gated edge
(zero elsewhere); ``theta_j`` is the edge's wire payload in the same call
(the engine delays scheduler kicks one round so the payload is on the
wire; the async executor kicks staleness-gated edges in-round from its
ledger). The kick term is compiled only when the scheduler can gate
(``TopologyConfig.can_gate``): a lam + 0.0 would flip -0.0 bits and break
the static-path bit-identity pin.

SMEM footprint note: the block->leaf table costs 4 bytes per block — pick
``block_size`` >= 64k at LM scale so a multi-billion-parameter vector keeps
the table in the tens of KB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pad1(x, padded):
    (n,) = x.shape
    return x if padded == n else jnp.pad(x, (0, padded - n))


def _kernel(scalars_ref, theta_ref, lam_ref, nbr_ref, bar_ref, barp_ref,
            theta_out, lam_out, rsq_out, ssq_out):
    eta_sum = scalars_ref[0]
    eta_node = scalars_ref[1]
    step = scalars_ref[2]
    theta = theta_ref[...].astype(jnp.float32)
    lam = lam_ref[...].astype(jnp.float32)
    nbr = nbr_ref[...].astype(jnp.float32)
    bar = bar_ref[...].astype(jnp.float32)
    barp = barp_ref[...].astype(jnp.float32)

    theta_new = theta - step * (2.0 * lam + eta_sum * (theta - nbr))
    lam_new = lam + 0.5 * eta_sum * (theta_new - nbr)
    theta_out[...] = theta_new.astype(theta_out.dtype)
    lam_out[...] = lam_new.astype(lam_out.dtype)
    rsq_out[0] = jnp.sum((theta_new - bar) ** 2)
    dbar = bar - barp
    ssq_out[0] = (eta_node * eta_node) * jnp.sum(dbar * dbar)


@functools.partial(jax.jit,
                   static_argnames=("block_size", "interpret"))
def consensus_update(theta, lam, nbr_avg, theta_bar, theta_bar_prev, *,
                     eta_sum, eta_node, step_size,
                     block_size: int = 65536, interpret: bool = True):
    """All tensor args are flat [N] vectors; N need NOT be a block multiple.

    Non-multiple N is zero-padded internally: zero inputs are a fixed point
    of the update (theta_new = lam_new = 0) and contribute exactly 0 to both
    residual reductions, so the padded sums equal the masked ones.

    Returns (theta_new [N], lam_new [N], r_sq scalar, s_sq scalar).
    """
    (n,) = theta.shape
    block_size = min(block_size, n)
    padded = -(-n // block_size) * block_size
    args = [_pad1(x, padded)
            for x in (theta, lam, nbr_avg, theta_bar, theta_bar_prev)]
    grid = (padded // block_size,)
    scalars = jnp.stack([jnp.asarray(eta_sum, jnp.float32),
                         jnp.asarray(eta_node, jnp.float32),
                         jnp.asarray(step_size, jnp.float32)])

    vec = pl.BlockSpec((block_size,), lambda i: (i,))
    theta_new, lam_new, rsq, ssq = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            vec, vec, vec, vec, vec,
        ],
        out_specs=[
            vec, vec,
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded,), theta.dtype),
            jax.ShapeDtypeStruct((padded,), lam.dtype),
            jax.ShapeDtypeStruct(grid, jnp.float32),
            jax.ShapeDtypeStruct(grid, jnp.float32),
        ],
        interpret=interpret,
    )(scalars, *args)
    return theta_new[:n], lam_new[:n], rsq.sum(), ssq.sum()


def _round_kernel(deg, per_block, block_leaf_ref, node_ref, esym_ref,
                  scale_ref, theta_ref, lam_ref, barp_ref, wires_ref,
                  theta_out, lam_out, bar_out, rsq_out, ssq_out):
    b = pl.program_id(1)
    # per-leaf scales resolve through the block->leaf table; per-block
    # scales (the fp8 codecs) index by the block id directly
    li = b if per_block else block_leaf_ref[b]
    alpha = node_ref[0, 0]
    eta_sum = node_ref[1, 0]
    eta_node = node_ref[2, 0]

    theta = theta_ref[0, :].astype(jnp.float32)
    lam = lam_ref[0, :].astype(jnp.float32)
    barp = barp_ref[0, :].astype(jnp.float32)

    nbr_w = jnp.zeros_like(theta)
    nbr_p = jnp.zeros_like(theta)
    for d in range(deg):                      # static unroll over offsets
        x = wires_ref[d, 0, :].astype(jnp.float32) * scale_ref[d, 0, li]
        nbr_w = nbr_w + esym_ref[d, 0] * x
        nbr_p = nbr_p + x
    bar = nbr_p * (1.0 / deg)
    nbr = nbr_w / jnp.maximum(eta_sum, 1e-12)

    theta_new = theta - alpha * (2.0 * lam + eta_sum * (theta - nbr))
    lam_new = lam + 0.5 * eta_sum * (theta_new - nbr)
    theta_out[0, :] = theta_new.astype(theta_out.dtype)
    lam_out[0, :] = lam_new.astype(lam_out.dtype)
    bar_out[0, :] = bar.astype(bar_out.dtype)
    rsq_out[0, 0] = jnp.sum((theta_new - bar) ** 2)
    dbar = bar - barp
    ssq_out[0, 0] = (eta_node * eta_node) * jnp.sum(dbar * dbar)


def _row_kernel(deg, block_size, per_block, block_leaf_ref, node_ref,
                esym_ref, scale_ref, theta_ref, lam_ref, barp_ref, wires_ref,
                theta_out, lam_out, bar_out, rsq_out, ssq_out):
    """Whole-row variant of ``_round_kernel`` (one grid step per node).

    Used in interpret mode, where there is no VMEM limit and the per-grid-
    step interpreter dispatch (~ms on CPU) would otherwise dominate: the
    8-block tiling that keeps the TPU kernel inside VMEM buys nothing under
    the interpreter. The math and the residual reduction ORDER (blockwise
    partial sums) are identical to the blocked kernel, so both variants
    match ``ref.consensus_round_ref`` to the same round-off.
    """
    alpha = node_ref[0, 0]
    eta_sum = node_ref[1, 0]
    eta_node = node_ref[2, 0]
    theta = theta_ref[0, :].astype(jnp.float32)
    lam = lam_ref[0, :].astype(jnp.float32)
    barp = barp_ref[0, :].astype(jnp.float32)

    bl = block_leaf_ref[...]
    nbr_w = jnp.zeros_like(theta)
    nbr_p = jnp.zeros_like(theta)
    for d in range(deg):
        row = scale_ref[d, 0, :] if per_block else scale_ref[d, 0, :][bl]
        scale_vec = jnp.repeat(row, block_size,
                               total_repeat_length=theta.shape[0])
        x = wires_ref[d, 0, :].astype(jnp.float32) * scale_vec
        nbr_w = nbr_w + esym_ref[d, 0] * x
        nbr_p = nbr_p + x
    bar = nbr_p * (1.0 / deg)
    nbr = nbr_w / jnp.maximum(eta_sum, 1e-12)

    theta_new = theta - alpha * (2.0 * lam + eta_sum * (theta - nbr))
    lam_new = lam + 0.5 * eta_sum * (theta_new - nbr)
    theta_out[0, :] = theta_new.astype(theta_out.dtype)
    lam_out[0, :] = lam_new.astype(lam_out.dtype)
    bar_out[0, :] = bar.astype(bar_out.dtype)

    def blocksum(v):                    # same order as the blocked kernel
        return v.reshape(-1, block_size).sum(axis=-1).sum()

    rsq_out[0, 0] = blocksum((theta_new - bar) ** 2)
    dbar = bar - barp
    ssq_out[0, 0] = (eta_node * eta_node) * blocksum(dbar * dbar)


def _round_kernel_masked(deg, has_kick, per_block, block_leaf_ref, node_ref,
                         esym_ref, barw_ref, *refs):
    """Edge-gated variant of ``_round_kernel`` (see module docstring)."""
    if has_kick:
        (kick_ref, scale_ref, theta_ref, lam_ref, barp_ref, wires_ref,
         theta_out, lam_out, bar_out, rsq_out, ssq_out) = refs
    else:
        (scale_ref, theta_ref, lam_ref, barp_ref, wires_ref,
         theta_out, lam_out, bar_out, rsq_out, ssq_out) = refs
    b = pl.program_id(1)
    li = b if per_block else block_leaf_ref[b]
    alpha = node_ref[0, 0]
    eta_sum = node_ref[1, 0]
    eta_node = node_ref[2, 0]
    inv_deg = node_ref[3, 0]

    theta = theta_ref[0, :].astype(jnp.float32)
    lam = lam_ref[0, :].astype(jnp.float32)
    barp = barp_ref[0, :].astype(jnp.float32)

    nbr_w = jnp.zeros_like(theta)
    nbr_p = jnp.zeros_like(theta)
    kick_x = jnp.zeros_like(theta)
    ksum = jnp.float32(0.0)
    for d in range(deg):                      # static unroll over offsets
        x = wires_ref[d, 0, :].astype(jnp.float32) * scale_ref[d, 0, li]
        nbr_w = nbr_w + esym_ref[d, 0] * x
        nbr_p = nbr_p + barw_ref[d, 0] * x
        if has_kick:
            kick_x = kick_x + kick_ref[d, 0] * x
            ksum = ksum + kick_ref[d, 0]
    bar = nbr_p * inv_deg
    nbr = nbr_w / jnp.maximum(eta_sum, 1e-12)

    theta_new = theta - alpha * (2.0 * lam + eta_sum * (theta - nbr))
    lam_new = lam + 0.5 * eta_sum * (theta_new - nbr)
    if has_kick:
        # zero-kick: absorb newly-gated edges' final consensus force
        # 0.5 sum_d kick_d (theta - x_d) into the dual (round-start iterate)
        lam_new = lam_new + 0.5 * (ksum * theta - kick_x)
    theta_out[0, :] = theta_new.astype(theta_out.dtype)
    lam_out[0, :] = lam_new.astype(lam_out.dtype)
    bar_out[0, :] = bar.astype(bar_out.dtype)
    rsq_out[0, 0] = jnp.sum((theta_new - bar) ** 2)
    dbar = bar - barp
    ssq_out[0, 0] = (eta_node * eta_node) * jnp.sum(dbar * dbar)


def _row_kernel_masked(deg, block_size, has_kick, per_block, block_leaf_ref,
                       node_ref, esym_ref, barw_ref, *refs):
    """Edge-gated variant of ``_row_kernel`` (whole-row interpret tiling)."""
    if has_kick:
        (kick_ref, scale_ref, theta_ref, lam_ref, barp_ref, wires_ref,
         theta_out, lam_out, bar_out, rsq_out, ssq_out) = refs
    else:
        (scale_ref, theta_ref, lam_ref, barp_ref, wires_ref,
         theta_out, lam_out, bar_out, rsq_out, ssq_out) = refs
    alpha = node_ref[0, 0]
    eta_sum = node_ref[1, 0]
    eta_node = node_ref[2, 0]
    inv_deg = node_ref[3, 0]
    theta = theta_ref[0, :].astype(jnp.float32)
    lam = lam_ref[0, :].astype(jnp.float32)
    barp = barp_ref[0, :].astype(jnp.float32)

    bl = block_leaf_ref[...]
    nbr_w = jnp.zeros_like(theta)
    nbr_p = jnp.zeros_like(theta)
    kick_x = jnp.zeros_like(theta)
    ksum = jnp.float32(0.0)
    for d in range(deg):
        row = scale_ref[d, 0, :] if per_block else scale_ref[d, 0, :][bl]
        scale_vec = jnp.repeat(row, block_size,
                               total_repeat_length=theta.shape[0])
        x = wires_ref[d, 0, :].astype(jnp.float32) * scale_vec
        nbr_w = nbr_w + esym_ref[d, 0] * x
        nbr_p = nbr_p + barw_ref[d, 0] * x
        if has_kick:
            kick_x = kick_x + kick_ref[d, 0] * x
            ksum = ksum + kick_ref[d, 0]
    bar = nbr_p * inv_deg
    nbr = nbr_w / jnp.maximum(eta_sum, 1e-12)

    theta_new = theta - alpha * (2.0 * lam + eta_sum * (theta - nbr))
    lam_new = lam + 0.5 * eta_sum * (theta_new - nbr)
    if has_kick:
        lam_new = lam_new + 0.5 * (ksum * theta - kick_x)
    theta_out[0, :] = theta_new.astype(theta_out.dtype)
    lam_out[0, :] = lam_new.astype(lam_out.dtype)
    bar_out[0, :] = bar.astype(bar_out.dtype)

    def blocksum(v):                    # same order as the blocked kernel
        return v.reshape(-1, block_size).sum(axis=-1).sum()

    rsq_out[0, 0] = blocksum((theta_new - bar) ** 2)
    dbar = bar - barp
    ssq_out[0, 0] = (eta_node * eta_node) * blocksum(dbar * dbar)


def _row_round(theta, lam, bar_prev, wires, scales, e_sym, node_scalars,
               block_leaf_arr, *, block_size, interpret, bar_w=None,
               kick_w=None, scales_per_block=False):
    j, total = theta.shape
    deg = wires.shape[0]
    masked = bar_w is not None
    vec = pl.BlockSpec((1, total), lambda i: (i, 0))
    nscal = 4 if masked else 3
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),       # block -> leaf
        pl.BlockSpec((nscal, 1), lambda i: (0, i),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((deg, 1), lambda i: (0, i),
                     memory_space=pltpu.SMEM),
    ]
    args = [block_leaf_arr, node_scalars, e_sym.astype(jnp.float32)]
    if masked:
        in_specs.append(pl.BlockSpec((deg, 1), lambda i: (0, i),
                                     memory_space=pltpu.SMEM))
        args.append(bar_w.astype(jnp.float32))
    if kick_w is not None:
        in_specs.append(pl.BlockSpec((deg, 1), lambda i: (0, i),
                                     memory_space=pltpu.SMEM))
        args.append(kick_w.astype(jnp.float32))
    in_specs += [
        pl.BlockSpec((deg, 1, scales.shape[-1]), lambda i: (0, i, 0),
                     memory_space=pltpu.SMEM),
        vec, vec, vec,
        pl.BlockSpec((deg, 1, total), lambda i: (0, i, 0)),
    ]
    args += [scales.astype(jnp.float32), theta, lam, bar_prev, wires]
    alias_base = len(in_specs) - 4                    # position of theta
    kernel = (functools.partial(_row_kernel_masked, deg, block_size,
                                kick_w is not None, scales_per_block)
              if masked
              else functools.partial(_row_kernel, deg, block_size,
                                     scales_per_block))
    return pl.pallas_call(
        kernel,
        grid=(j,),
        in_specs=in_specs,
        out_specs=[vec, vec, vec,
                   pl.BlockSpec((1, 1), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((j, total), theta.dtype),
            jax.ShapeDtypeStruct((j, total), lam.dtype),
            jax.ShapeDtypeStruct((j, total), jnp.float32),
            jax.ShapeDtypeStruct((j, 1), jnp.float32),
            jax.ShapeDtypeStruct((j, 1), jnp.float32),
        ],
        input_output_aliases={alias_base: 0, alias_base + 1: 1,
                              alias_base + 2: 2},
        interpret=interpret,
    )(*args)


@functools.partial(jax.jit, static_argnames=("block_leaf", "block_size",
                                             "interpret", "whole_rows",
                                             "scales_per_block"))
def consensus_round(theta, lam, bar_prev, wires, scales, e_sym,
                    alpha, eta_sum, eta_node, *,
                    block_leaf: tuple[int, ...] | None, block_size: int,
                    interpret: bool = True,
                    whole_rows: bool | None = None,
                    bar_w=None, inv_deg=None, kick_w=None,
                    block_leaf_arr=None, scales_per_block: bool = False):
    """Whole-round fused kernel over the flat buffer.

    Args:
      theta, lam, bar_prev: [J, total] float buffers (total = blocks * bs).
      wires: [deg, J, total] rolled wire payloads — int8/fp8 (quantized) or
        any float dtype; row d holds theta_{(i+off_d) % J} at node i.
      scales: [deg, J, L] f32 per-leaf dequant scales (ones when the wire is
        uncompressed) — or, with ``scales_per_block``, [deg, J, num_blocks]
        per-BLOCK scales on the layout's block grid (the fp8 codecs).
      e_sym: [deg, J] f32 symmetrized per-edge penalties eta_sym_ij
        (edge-gated upstream for dynamic topologies: zero on masked edges).
      alpha, eta_sum, eta_node: [J] f32 per-node scalars.
      block_leaf: static tuple, owning leaf id per block (FlatLayout table).
      block_size: elements per block; must divide total.
      bar_w: optional [deg, J] f32 traced edge gates (1 = active) weighting
        the neighbor-mean accumulation — the dynamic-topology mask.
      inv_deg: optional [J] f32, 1 / active degree (0 for isolated/ghost
        nodes). Must be supplied together with ``bar_w``; both None selects
        the ungated PR 1 kernel (byte-identical math).
      kick_w: optional [deg, J] f32 zero-kick weights (masked variants
        only): the dual additionally absorbs
        ``0.5 * sum_d kick_w[d] * (theta - dequant(wire[d]))`` — the final
        consensus force of edges gated since the last round. Passing None
        compiles the kick-free kernel (bit-identical to PR 2).
      block_leaf_arr: optional TRACED [num_blocks] int32 block->leaf table
        replacing the static ``block_leaf`` tuple (pass ``block_leaf=None``
        then). The sharded engine uses this: under shard_map every device
        runs the same program on a DIFFERENT slab of the flat axis, so its
        slab's table must be data, not program. The table was already fed
        to the kernel as an SMEM operand — only the tracing changes.
      scales_per_block: static — ``scales`` carries one scalar per BLOCK
        (the fp8 codecs' granularity, ``repro.wire.DequantSpec``) instead
        of one per leaf; block b dequants from ``scales[b]`` directly, no
        block->leaf lookup. Under the sharded engine the scale rows shard
        with the slabs, so the LOCAL block id still indexes correctly.
        False keeps the per-leaf path bit-identical.

    Returns (theta_new [J, total], lam_new [J, total], bar [J, total] f32,
             r_sq [J], s_sq [J]).

    The input buffers theta/lam/bar_prev are aliased to the outputs
    theta_new/lam_new/bar, so with donated jit arguments XLA updates them
    in place.

    ``whole_rows`` (default: follow ``interpret``) switches to one grid
    step per node row — the interpreter tiling; the VMEM-sized blocked grid
    is for real TPU runs (and stays testable via ``whole_rows=False``).
    """
    j, total = theta.shape
    deg = wires.shape[0]
    assert total % block_size == 0, (total, block_size)
    nblocks = total // block_size
    assert (block_leaf is None) != (block_leaf_arr is None), \
        "exactly one of block_leaf / block_leaf_arr"
    masked = bar_w is not None
    assert masked == (inv_deg is not None), "bar_w and inv_deg travel together"
    assert kick_w is None or masked, "kick_w needs the masked kernel"

    rows = [jnp.asarray(alpha, jnp.float32),
            jnp.asarray(eta_sum, jnp.float32),
            jnp.asarray(eta_node, jnp.float32)]
    if masked:
        rows.append(jnp.asarray(inv_deg, jnp.float32))
    node_scalars = jnp.stack(rows)                    # [3|4, J]
    if block_leaf_arr is None:
        assert len(block_leaf) == nblocks, (len(block_leaf), nblocks)
        block_leaf_arr = jnp.asarray(block_leaf, jnp.int32)
    assert block_leaf_arr.shape == (nblocks,), (block_leaf_arr.shape, nblocks)
    if scales_per_block:
        assert scales.shape[-1] == nblocks, (scales.shape, nblocks)

    if interpret if whole_rows is None else whole_rows:
        tn, ln, bar, rsq, ssq = _row_round(
            theta, lam, bar_prev, wires, scales, e_sym, node_scalars,
            block_leaf_arr, block_size=block_size, interpret=interpret,
            bar_w=bar_w, kick_w=kick_w, scales_per_block=scales_per_block)
        return tn, ln, bar, rsq[:, 0], ssq[:, 0]

    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    vec = pl.BlockSpec((1, block_size), lambda i, b: (i, b))
    wire_spec = pl.BlockSpec((deg, 1, block_size), lambda i, b: (0, i, b))
    part = pl.BlockSpec((1, 1), lambda i, b: (i, b))

    nscal = node_scalars.shape[0]
    in_specs = [
        smem,                        # block -> leaf table
        pl.BlockSpec((nscal, 1), lambda i, b: (0, i),
                     memory_space=pltpu.SMEM),        # per-node scalars
        pl.BlockSpec((deg, 1), lambda i, b: (0, i),
                     memory_space=pltpu.SMEM),        # e_sym
    ]
    args = [block_leaf_arr, node_scalars, e_sym.astype(jnp.float32)]
    if masked:
        in_specs.append(pl.BlockSpec((deg, 1), lambda i, b: (0, i),
                                     memory_space=pltpu.SMEM))  # edge gates
        args.append(bar_w.astype(jnp.float32))
    if kick_w is not None:
        in_specs.append(pl.BlockSpec((deg, 1), lambda i, b: (0, i),
                                     memory_space=pltpu.SMEM))  # zero-kick
        args.append(kick_w.astype(jnp.float32))
    in_specs += [
        pl.BlockSpec((deg, 1, scales.shape[-1]), lambda i, b: (0, i, 0),
                     memory_space=pltpu.SMEM),        # dequant scales
        vec, vec, vec,               # theta, lam, bar_prev
        wire_spec,
    ]
    args += [scales.astype(jnp.float32), theta, lam, bar_prev, wires]
    ab = len(in_specs) - 4                            # position of theta

    kernel = (functools.partial(_round_kernel_masked, deg,
                                kick_w is not None, scales_per_block)
              if masked
              else functools.partial(_round_kernel, deg, scales_per_block))
    theta_new, lam_new, bar, rsq, ssq = pl.pallas_call(
        kernel,
        grid=(j, nblocks),
        in_specs=in_specs,
        out_specs=[vec, vec, vec, part, part],
        out_shape=[
            jax.ShapeDtypeStruct((j, total), theta.dtype),
            jax.ShapeDtypeStruct((j, total), lam.dtype),
            jax.ShapeDtypeStruct((j, total), jnp.float32),
            jax.ShapeDtypeStruct((j, nblocks), jnp.float32),
            jax.ShapeDtypeStruct((j, nblocks), jnp.float32),
        ],
        # in-place: theta->theta_new, lam->lam_new, bar_prev->bar
        input_output_aliases={ab: 0, ab + 1: 1, ab + 2: 2},
        interpret=interpret,
    )(*args)
    return theta_new, lam_new, bar, rsq.sum(axis=1), ssq.sum(axis=1)
