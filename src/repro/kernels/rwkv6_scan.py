"""Pallas chunked WKV6 scan — the RWKV6 recurrence as TPU matmuls.

The per-step recurrence
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T);   S_t = diag(w_t) S_{t-1} + k_t v_t^T
is O(T) sequential. The TPU-native adaptation blocks time into chunks of C
steps and turns the inner work into MXU matmuls (the standard linear-attention
chunking, re-derived for RWKV's per-channel decay):

with log-decays lw_t and  ls_t = sum_{j<t} lw_j  (exclusive cumsum within the
chunk), P = exp(ls_C) the full-chunk decay:

    y      = ((r*exp(ls)) @ S_in^T ... inter-chunk term)      [C, hd_v]
           + ((r_i . k_l * exp(ls_i - ls_{l+1}))_{l<i} + diag(r_i . u k_i)) @ v
    S_out  = diag(P) S_in + (k * exp(lsC - ls_{l+1}))^T @ v

All ratios are exp of non-positive differences => numerically safe.
Grid: (B, H, T/C) with the chunk axis sequential ("arbitrary"), S carried in
a [hd, hd] f32 VMEM scratch. Chunk C and head dim are the VMEM tile knobs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, sout_ref,
            s_ref, *, chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = s0_ref[...].astype(jnp.float32)

    r = r_ref[...].astype(jnp.float32)      # [C, hd]
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    lw = lw_ref[...].astype(jnp.float32)    # log decay, <= 0
    u = u_ref[...].astype(jnp.float32)      # [1, hd] bonus

    ls = jnp.cumsum(lw, axis=0) - lw        # exclusive cumsum  [C, hd]
    ls_total = ls[-1] + lw[-1]              # [hd] full-chunk log decay
    s_in = s_ref[...]                       # [hd, hd] (key x value)

    # inter-chunk: y_i += (r_i * exp(ls_i)) @ S_in       (exp(ls) <= 1: safe)
    r_s = r * jnp.exp(ls)
    y = jax.lax.dot_general(r_s, s_in, (((1,), (0,)), ((), ())))

    # intra-chunk: A[i, l] = sum_d r_i exp(ls_i - ls_{l+1}) k_l   (l < i).
    # The factored form exp(ls_i) * exp(-ls_{l+1}) overflows for strong decay
    # x long chunks; re-center both exponentials at half the chunk decay so
    # each factor stays within float32 range (|ls - c| <= |ls_total|/2).
    c = 0.5 * ls_total[None, :]
    r_dec = r * jnp.exp(ls - c)
    k_dec = k * jnp.exp(c - (ls + lw))      # k_l * exp(c - ls_{l+1})
    a = jax.lax.dot_general(r_dec, k_dec, (((1,), (1,)), ((), ())))
    ii = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    ll = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    a = jnp.where(ll < ii, a, 0.0)
    # current-step bonus: diag term r_i . (u * k_i)
    diag = jnp.sum(r * u * k, axis=1)
    a = a + jnp.where(ll == ii, diag[:, None], 0.0)
    y = y + jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())))
    y_ref[...] = y.astype(y_ref.dtype)

    # state update: S_out = diag(P) S_in + (k * exp(lsC - ls_{l+1}))^T @ v
    k_carry = k * jnp.exp(ls_total[None, :] - (ls + lw))
    s_new = jnp.exp(ls_total)[:, None] * s_in + jax.lax.dot_general(
        k_carry, v, (((0,), (0,)), ((), ())))
    s_ref[...] = s_new

    @pl.when(ci == num_chunks - 1)
    def _final():
        sout_ref[...] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, log_w, u, s0, *, chunk: int = 32,
               interpret: bool = True):
    """r,k,v,log_w: [B, H, T, hd]; u: [H, hd]; s0: [B, H, hd, hd].

    Returns (y [B, H, T, hd], s_final [B, H, hd, hd]).
    """
    b, h, t, hd = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    num_chunks = t // chunk
    grid = (b, h, num_chunks)

    kernel = functools.partial(_kernel, chunk=chunk, num_chunks=num_chunks)
    seq_spec = pl.BlockSpec((None, None, chunk, hd),
                            lambda bb, hh, ci: (bb, hh, ci, 0))
    y, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((None, 1, hd), lambda bb, hh, ci: (hh, 0, 0)),
            pl.BlockSpec((None, None, hd, hd),
                         lambda bb, hh, ci: (bb, hh, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((None, None, hd, hd),
                         lambda bb, hh, ci: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, hd), r.dtype),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u[:, None, :], s0)
    return y, s_out
