"""RWKV6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

Per head (dim hd), with receptance r, key k, value v, decay w in (0,1),
bonus u:
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          S in R^{hd x hd}

Token shift uses the RWKV6 dynamic ddlerp (low-rank data-dependent mix).
Reference = lax.scan; the Pallas chunked WKV kernel targets the TPU hot path.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.params import ParamDef

_MIX_RANK = 32
_DECAY_RANK = 64
_N_MIX = 5  # r, k, v, w, g

# dry-run FLOPs-accounting knob (see transformer.SCAN_UNROLL)
TIME_UNROLL = 1

# perf knob (§Perf hillclimb A): 0 = per-step lax.scan reference; >0 = chunked
# matmul formulation with this chunk length (the GSPMD mirror of the Pallas
# kernel). Cuts the time-scan trip count by the chunk factor and turns VPU
# outer products into MXU matmuls.
TIME_CHUNK = 0

# §Perf knob: force bf16 output on the row-parallel (TP) output projections.
# XLA otherwise all-reduces the f32 pre-convert dot partials — the dominant
# per-layer collective is the [B,S,D] activation psum, so this halves it.
PSUM_BF16 = False

# §Perf knob: replicate the tiny ddlerp/decay LoRA params instead of FSDP-
# sharding them. Sharding a [D, rank] weight's D on 'data' makes its product
# [B, S, D] carry D-on-data sharding that CONFLICTS with B-on-data activation
# sharding => GSPMD inserts full-activation reshards every layer.
LORA_REPLICATED = False


def _rp_matmul(a, w):
    """Row-parallel matmul whose psum wire dtype we control."""
    if PSUM_BF16:
        return jnp.einsum("...k,kd->...d", a, w,
                          preferred_element_type=jnp.bfloat16)
    return a @ w


def wkv_chunked(r, k, v, w, u, s0, chunk: int):
    """Chunked WKV6 (same math as kernels/rwkv6_scan, pure jnp).

    r,k,v,w: [B,S,H,hd] (w = decay in (0,1)); u: [H,hd]; s0: [B,H,hd,hd].
    """
    b, s, h, hd = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    lw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))
    split = lambda t: jnp.moveaxis(
        t.reshape(b, n, chunk, h, hd), 1, 0)          # [n,B,C,H,hd]
    rs_, ks_, vs_, lws_ = (split(t) for t in (r, k, v, lw))

    def body(state, inp):
        rc, kc, vc, lwc = inp                        # [B,C,H,hd]
        rc = rc.astype(jnp.float32)
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        ls = jnp.cumsum(lwc, axis=1) - lwc           # exclusive cumsum over C
        ls_tot = ls[:, -1] + lwc[:, -1]              # [B,H,hd]
        r_s = rc * jnp.exp(ls)
        y = jnp.einsum("bchk,bhkv->bchv", r_s, state)
        c_mid = 0.5 * ls_tot[:, None]                # re-centering (kernel)
        r_dec = rc * jnp.exp(ls - c_mid)
        k_dec = kc * jnp.exp(c_mid - ls - lwc)
        a = jnp.einsum("bchk,bdhk->bhcd", r_dec, k_dec)
        ii = jax.lax.broadcasted_iota(jnp.int32, a.shape, 2)
        ll = jax.lax.broadcasted_iota(jnp.int32, a.shape, 3)
        a = jnp.where(ll < ii, a, 0.0)
        # current-step bonus on the diagonal: sum_d r*u*k
        diag = jnp.sum(rc * u.astype(jnp.float32)[None, None] * kc, axis=-1)
        diag_t = jnp.swapaxes(diag, 1, 2)            # [B,H,C]
        a = a + jnp.where(ll == ii, diag_t[:, :, :, None], 0.0)
        y = y + jnp.einsum("bhcd,bdhv->bchv", a, vc)
        k_carry = kc * jnp.exp(ls_tot[:, None] - ls - lwc)
        s_new = jnp.exp(ls_tot)[..., None] * state \
            + jnp.einsum("bchk,bchv->bhkv", k_carry, vc)
        return s_new, y.astype(r.dtype)

    s_last, ys = jax.lax.scan(body, s0.astype(jnp.float32),
                              (rs_, ks_, vs_, lws_),
                              unroll=min(TIME_UNROLL, n))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, hd)
    return y, s_last


class RWKVState(NamedTuple):
    s: jax.Array        # [B, H, hd, hd]  WKV state
    prev_tm: jax.Array  # [B, D] last input to time-mix (token shift)
    prev_cm: jax.Array  # [B, D] last input to channel-mix


def rwkv_defs(cfg: ArchConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    hh, hd = cfg.n_heads, cfg.head_dim
    return {
        # time-mix
        "maa_x": ParamDef((d,), (None,), dtype, init="zeros"),
        "maa": ParamDef((_N_MIX, d), (None, None), dtype, init="zeros"),
        "tm_w1": ParamDef((d, _N_MIX * _MIX_RANK),
                          (None if LORA_REPLICATED else "fsdp", None), dtype),
        "tm_w2": ParamDef((_N_MIX, _MIX_RANK, d),
                          (None, None, None if LORA_REPLICATED else "fsdp"),
                          dtype),
        "td_w1": ParamDef((d, _DECAY_RANK),
                          (None if LORA_REPLICATED else "fsdp", None), dtype),
        "td_w2": ParamDef((_DECAY_RANK, d),
                          (None, None if LORA_REPLICATED else "fsdp"), dtype),
        "decay_base": ParamDef((d,), (None,), dtype, init="zeros"),
        "bonus_u": ParamDef((hh, hd), (None, None), dtype, init="zeros"),
        "wr": ParamDef((d, d), ("fsdp", "heads_flat"), dtype),
        "wk": ParamDef((d, d), ("fsdp", "heads_flat"), dtype),
        "wv": ParamDef((d, d), ("fsdp", "heads_flat"), dtype),
        "wg": ParamDef((d, d), ("fsdp", "heads_flat"), dtype),
        "wo_tm": ParamDef((d, d), ("heads_flat", "fsdp"), dtype),
        "ln_x": ParamDef((d,), (None,), dtype, init="zeros"),
        # channel-mix
        "cm_maa_k": ParamDef((d,), (None,), dtype, init="zeros"),
        "cm_maa_r": ParamDef((d,), (None,), dtype, init="zeros"),
        "cm_wk": ParamDef((d, f), ("fsdp", "mlp"), dtype),
        "cm_wv": ParamDef((f, d), ("mlp", "fsdp"), dtype),
        "cm_wr": ParamDef((d, d), ("fsdp", None), dtype),
    }


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """shift(x)_t = x_{t-1}; position 0 uses `prev` (zeros at seq start)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(p: dict, x: jax.Array, xs: jax.Array):
    """RWKV6 dynamic 5-way token-shift mix. Returns [5, B, S, D]."""
    dx = xs - x
    base = x + dx * p["maa_x"][None, None, :]
    lora = jnp.tanh(base @ p["tm_w1"])                  # [B,S,5*rank]
    b, s, _ = x.shape
    lora = lora.reshape(b, s, _N_MIX, _MIX_RANK)
    dyn = jnp.einsum("bsnr,nrd->nbsd", lora, p["tm_w2"])
    mix = p["maa"][:, None, None, :] + dyn              # [5,B,S,D]
    return x[None] + dx[None] * mix


def wkv_ref(r, k, v, w, u, s0):
    """Reference WKV recurrence.

    r,k,v: [B,S,H,hd]; w: [B,S,H,hd] decay in (0,1); u: [H,hd];
    s0: [B,H,hd,hd]. Returns (y [B,S,H,hd], s_final).
    """
    def step(s, inp):
        rt, kt, vt, wt = inp            # [B,H,hd] each
        kv = kt[..., :, None] * vt[..., None, :]        # [B,H,hd,hd]
        y = jnp.einsum("bhk,bhkv->bhv", rt,
                       s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32)
               for t in (r, k, v, w))
    s_last, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs,
                              unroll=min(TIME_UNROLL, r.shape[1]))
    return jnp.moveaxis(ys, 0, 1), s_last


def _group_norm(x: jax.Array, scale: jax.Array, heads: int,
                eps: float) -> jax.Array:
    b, s, d = x.shape
    xh = x.reshape(b, s, heads, -1).astype(jnp.float32)
    mean = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mean) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(b, s, d)
            * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def time_mix(cfg: ArchConfig, p: dict, x: jax.Array,
             state: RWKVState | None, use_kernel: bool = False
             ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (y, s_final, last_x)."""
    b, s, d = x.shape
    hh, hd = cfg.n_heads, cfg.head_dim
    prev = state.prev_tm if state is not None else jnp.zeros((b, d), x.dtype)
    xs = _token_shift(x, prev)
    mr, mk, mv, mw, mg = _ddlerp(p, x, xs)
    r = (mr @ p["wr"]).reshape(b, s, hh, hd)
    k = (mk @ p["wk"]).reshape(b, s, hh, hd)
    v = (mv @ p["wv"]).reshape(b, s, hh, hd)
    g = jax.nn.silu(mg @ p["wg"])
    decay_logit = p["decay_base"][None, None, :] \
        + jnp.tanh(mw @ p["td_w1"]) @ p["td_w2"]
    w = jnp.exp(-jnp.exp(decay_logit.astype(jnp.float32)))
    w = w.reshape(b, s, hh, hd)
    s0 = state.s if state is not None else jnp.zeros((b, hh, hd, hd),
                                                     jnp.float32)
    if use_kernel:
        from repro.kernels import ops as kops
        y, s_last = kops.rwkv6_scan(r, k, v, w, p["bonus_u"], s0)
    elif TIME_CHUNK > 0 and s > 1:
        y, s_last = wkv_chunked(r, k, v, w, p["bonus_u"], s0, TIME_CHUNK)
    else:
        y, s_last = wkv_ref(r, k, v, w, p["bonus_u"], s0)
    y = _group_norm(y.astype(x.dtype).reshape(b, s, d), p["ln_x"], hh,
                    cfg.norm_eps * 64)
    y = _rp_matmul(y * g, p["wo_tm"])
    return y, s_last, x[:, -1, :]


def channel_mix(cfg: ArchConfig, p: dict, x: jax.Array,
                state: RWKVState | None) -> tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    prev = state.prev_cm if state is not None else jnp.zeros((b, d), x.dtype)
    xs = _token_shift(x, prev)
    xk = x + (xs - x) * p["cm_maa_k"][None, None, :]
    xr = x + (xs - x) * p["cm_maa_r"][None, None, :]
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    rr = jax.nn.sigmoid(xr @ p["cm_wr"])
    return rr * _rp_matmul(kk, p["cm_wv"]), x[:, -1, :]
