"""Causal (optionally sliding-window) GQA attention, train/prefill/decode.

The einsum/GSPMD path is the canonical implementation (and what the dry-run
lowers, so cost_analysis sees real FLOPs). The Pallas flash kernel in
``repro.kernels`` is the TPU hot-path replacement, validated against
``flash_ref`` here; switch with ``use_kernel=True``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models.layers import apply_rope, rms_norm, rope_table
from repro.models.params import ParamDef

NEG_INF = -1e30

# §Perf knobs (hillclimb B), defaults = baseline behavior:
# SERIAL_CHUNKS: thread an optimization_barrier between query chunks so the
#   scheduler cannot keep every chunk's f32 logits alive at once (peak-memory
#   fix for 32k prefill).
# PROBS_BF16: store masked logits/probs in bf16 (max-subtraction still f32) —
#   halves attention HBM traffic at <=1e-2 softmax error.
SERIAL_CHUNKS = False
PROBS_BF16 = False
ATTN_CHUNK = 1024       # query-chunk length for the full-sequence path
# Pad query heads up to a multiple (0 = off). Archs whose head count does
# not divide the TP axis (qwen2: 28 heads vs TP=16, hymba: 25) otherwise
# REPLICATE attention over the model axis — a 16x memory/compute waste.
# Dummy heads have zero out-projection rows => numerically exact.
PAD_HEADS_MULT = 0


def eff_heads(cfg: ArchConfig) -> int:
    """Padded query-head count: a multiple of lcm(PAD_HEADS_MULT, kv) so the
    GQA repeat stays integral (hymba: 25 q / 5 kv -> 80 at TP=16).

    Dummy heads have zero out-projection rows, so they contribute nothing;
    note that when the repeat factor changes, the real-head -> kv grouping
    changes too — identical capacity trained from scratch, but NOT a
    drop-in remap for pretrained checkpoints (DESIGN.md §5b).
    """
    import math
    h = cfg.n_heads
    if PAD_HEADS_MULT and h % PAD_HEADS_MULT:
        step = math.lcm(PAD_HEADS_MULT, max(cfg.n_kv_heads, 1))
        h = ((h + step - 1) // step) * step
    return h


class KVCache(NamedTuple):
    k: jax.Array     # [B, S_cache, K, hd]
    v: jax.Array     # [B, S_cache, K, hd]
    pos: jax.Array   # [] int32 — next write position (ring for sliding)


def attn_defs(cfg: ArchConfig, dtype) -> dict:
    d, h, k, hd = cfg.d_model, eff_heads(cfg), cfg.n_kv_heads, cfg.head_dim
    out = {
        "wq": ParamDef((d, h, hd), ("fsdp", "heads", None), dtype),
        "wk": ParamDef((d, k, hd), ("fsdp", "kv_heads", None), dtype),
        "wv": ParamDef((d, k, hd), ("fsdp", "kv_heads", None), dtype),
        "wo": ParamDef((h, hd, d), ("heads", None, "fsdp"), dtype),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamDef((h, hd), ("heads", None), dtype, init="zeros")
        out["bk"] = ParamDef((k, hd), ("kv_heads", None), dtype, init="zeros")
        out["bv"] = ParamDef((k, hd), ("kv_heads", None), dtype, init="zeros")
    if cfg.qk_norm:
        out["qn"] = ParamDef((hd,), (None,), dtype, init="zeros")
        out["kn"] = ParamDef((hd,), (None,), dtype, init="zeros")
    return out


def _project_qkv(cfg: ArchConfig, p: dict, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"][None, None]
        k = k + p["bk"][None, None]
        v = v + p["bv"][None, None]
    if cfg.qk_norm:
        q = rms_norm(q, p["qn"], cfg.norm_eps)
        k = rms_norm(k, p["kn"], cfg.norm_eps)
    return q, k, v


def _repeat_kv(x: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return x
    b, s, k, hd = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :],
                            (b, s, k, n_rep, hd)).reshape(b, s, k * n_rep, hd)


def flash_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
              window: int = 0, q_offset: int | jax.Array = 0) -> jax.Array:
    """Reference attention. q: [B,Sq,H,hd]; k,v: [B,Sk,H,hd] (post-GQA)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None] + q_offset      # absolute query positions
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    if PROBS_BF16:
        # store the post-max-subtraction probs in bf16: halves attention HBM
        # traffic; the max-subtraction and the normalizer stay f32
        m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
        probs16 = jnp.exp(logits - m).astype(jnp.bfloat16)
        denom = probs16.astype(jnp.float32).sum(axis=-1, keepdims=True)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs16.astype(q.dtype), v)
        return out / jnp.swapaxes(denom, 1, 2).astype(out.dtype)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_causal_attention(q, k, v, *, window: int = 0,
                             chunk: int = 1024) -> jax.Array:
    """Memory-bounded causal attention: statically unrolled query blocks.

    Each query block attends only to K/V up to its own end (static slice) —
    the upper-triangle FLOPs of the naive einsum are never issued and the
    logits working set is [B, H, chunk, <=S] instead of [B, H, S, S].
    With a sliding window the K/V slice start is also static, so long-context
    prefill for windowed archs is O(S * window). This is the GSPMD analogue
    of the Pallas flash kernel (which owns the on-TPU tiling).
    """
    b, sq, h, hd = q.shape
    if sq <= chunk:
        return flash_ref(q, k, v, causal=True, window=window)
    assert sq % chunk == 0, (sq, chunk)
    outs = []
    prev = None
    for i in range(sq // chunk):
        q_blk = jax.lax.slice_in_dim(q, i * chunk, (i + 1) * chunk, axis=1)
        if SERIAL_CHUNKS and prev is not None:
            # artificial dependence: chunk i+1 may not start before chunk i
            # finishes => only one chunk's f32 logits are ever live
            q_blk, prev = jax.lax.optimization_barrier((q_blk, prev))
        k_end = (i + 1) * chunk
        k_start = max(0, i * chunk - window + 1) if window > 0 else 0
        # align to chunk for tidy tiles
        k_start = (k_start // chunk) * chunk
        k_blk = jax.lax.slice_in_dim(k, k_start, k_end, axis=1)
        v_blk = jax.lax.slice_in_dim(v, k_start, k_end, axis=1)
        out = flash_ref(q_blk, k_blk, v_blk, causal=True,
                        window=window, q_offset=i * chunk - k_start)
        outs.append(out)
        prev = out
    return jnp.concatenate(outs, axis=1)


def attention(cfg: ArchConfig, p: dict, x: jax.Array, cos, sin,
              use_kernel: bool = False, chunk: int | None = None
              ) -> jax.Array:
    """Full-sequence path (train / prefill). x: [B, S, D]."""
    if chunk is None:
        chunk = ATTN_CHUNK
    q, k, v = _project_qkv(cfg, p, x)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    n_rep = q.shape[2] // k.shape[2]       # shape-driven (head padding)
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True,
                                   window=cfg.sliding_window)
    else:
        out = chunked_causal_attention(q, k, v, window=cfg.sliding_window,
                                       chunk=chunk)
    out = shard(out, "batch", None, "heads", None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ------------------------------------------------------------- decoding -----
def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> KVCache:
    """Sliding-window archs keep a ring buffer of `window`, else full S."""
    s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, s, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   pos=jnp.zeros((), jnp.int32))


def decode_attention(cfg: ArchConfig, p: dict, x: jax.Array,
                     cache: KVCache, pos: jax.Array,
                     rope_cos_full, rope_sin_full
                     ) -> tuple[jax.Array, KVCache]:
    """One-token step. x: [B, 1, D]; pos: [] absolute position."""
    q, k, v = _project_qkv(cfg, p, x)
    cos = jax.lax.dynamic_slice_in_dim(rope_cos_full, pos, 1)
    sin = jax.lax.dynamic_slice_in_dim(rope_sin_full, pos, 1)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    s_cache = cache.k.shape[1]
    write = pos % s_cache if cfg.sliding_window else pos
    k_all = jax.lax.dynamic_update_slice_in_dim(cache.k, k, write, axis=1)
    v_all = jax.lax.dynamic_update_slice_in_dim(cache.v, v, write, axis=1)

    n_rep = q.shape[2] // k_all.shape[2]   # shape-driven (head padding)
    kr, vr = _repeat_kv(k_all, n_rep), _repeat_kv(v_all, n_rep)
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.head_dim, jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) * scale
    kpos = jnp.arange(s_cache)
    if cfg.sliding_window:
        valid = (kpos <= write) | (pos >= s_cache)   # ring buffer occupancy
    else:
        valid = kpos <= pos
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vr)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, KVCache(k=k_all, v=v_all, pos=pos + 1)


def make_rope(cfg: ArchConfig, seq_len: int, dtype=jnp.float32):
    return rope_table(seq_len, cfg.head_dim, cfg.rope_theta, dtype)
