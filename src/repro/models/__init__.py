"""Model zoo: unified decoder covering all assigned architecture families."""
from repro.models.model import (Model, arch_rules, build_model, input_specs,
                                input_spec_shardings, make_batch)

__all__ = ["Model", "arch_rules", "build_model", "input_specs",
           "input_spec_shardings", "make_batch"]
