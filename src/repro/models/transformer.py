"""Decoder stack: block definitions, scan-over-layers forward, decode step.

One generic block covers all assigned families:
  * dense / moe / audio / vlm : pre-norm attention + (SwiGLU | MoE) FFN
  * hybrid (hymba)            : attention and SSM heads run in PARALLEL on the
                                same normed input, outputs averaged, then FFN
  * ssm (rwkv6)               : RWKV time-mix + channel-mix (attention-free)

Layers are stacked on a leading L axis and driven by ``lax.scan`` (one trace
per unique block => small HLO, fast multi-arch dry-runs), with per-layer
gradient checkpointing (remat) for training.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rwkv6 as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (embed_defs, embed_tokens, mlp_apply,
                                 mlp_defs, rms_norm, unembed)
from repro.models.params import ParamDef


# ----------------------------------------------------------- definitions ----
def block_defs(cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    out: dict[str, Any] = {
        "ln1": ParamDef((d,), (None,), dtype, init="zeros"),
        "ln2": ParamDef((d,), (None,), dtype, init="zeros"),
    }
    if cfg.rwkv:
        out["rwkv"] = rwkv_lib.rwkv_defs(cfg, dtype)
        return out
    out["attn"] = attn_lib.attn_defs(cfg, dtype)
    if cfg.ssm_state:
        out["ssm"] = ssm_lib.ssm_defs(cfg, dtype)
    if cfg.moe is not None:
        out["moe"] = moe_lib.moe_defs(cfg, dtype)
    else:
        out["mlp"] = mlp_defs(cfg, dtype)
    return out


def stacked_defs(cfg: ArchConfig, dtype) -> dict:
    """All model parameters; block leaves get a leading layer axis."""
    blk = block_defs(cfg, dtype)

    def add_layer_axis(p: ParamDef) -> ParamDef:
        return ParamDef((cfg.n_layers,) + p.shape,
                        (None,) + p.logical_axes, p.dtype, p.init, p.scale)

    blocks = jax.tree_util.tree_map(
        add_layer_axis, blk, is_leaf=lambda x: isinstance(x, ParamDef))
    out = dict(embed_defs(cfg, dtype))
    out["blocks"] = blocks
    out["final_norm"] = ParamDef((cfg.d_model,), (None,), dtype, init="zeros")
    return out


# ------------------------------------------------------------- forward ------
def _block_full(cfg: ArchConfig, p: dict, x: jax.Array, cos, sin,
                decode_moe: bool = False) -> jax.Array:
    """Full-sequence block (train / prefill)."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.rwkv:
        y, _, _ = rwkv_lib.time_mix(cfg, p["rwkv"], h, None)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        y2, _ = rwkv_lib.channel_mix(cfg, p["rwkv"], h2, None)
        return x + y2
    y = attn_lib.attention(cfg, p["attn"], h, cos, sin)
    if cfg.ssm_state:
        y_ssm, _ = ssm_lib.ssm_apply(cfg, p["ssm"], h)
        y = 0.5 * (y + y_ssm)            # hymba: parallel heads, averaged
    x = x + y
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        y2 = moe_lib.moe_apply(cfg, p["moe"], h2, decode=decode_moe)
    else:
        y2 = mlp_apply(p["mlp"], h2)
    return x + y2


# Dry-run knob: lax.scan hides per-layer FLOPs from cost_analysis (the while
# body is counted once). The dry-run sets this to the layer count to unroll
# the stack so the compiled module exposes true whole-model FLOPs/bytes.
SCAN_UNROLL = 1


def forward(cfg: ArchConfig, params: dict, *, tokens=None, embeds=None,
            remat: bool = True) -> jax.Array:
    """Full-sequence forward to logits. tokens [B,S] or embeds [B,S,D]."""
    if embeds is None:
        x = embed_tokens(params, tokens)
    else:
        x = shard(embeds, "batch", None, None)
    seq = x.shape[1]
    x = x.astype(jnp.dtype(cfg.dtype))
    cos = sin = None
    if not cfg.rwkv:
        cos, sin = attn_lib.make_rope(cfg, seq)

    def body(carry, layer_params):
        y = _block_full(cfg, layer_params, carry, cos, sin)
        y = shard(y, "batch", None, None)
        return y, None

    if remat:
        body = jax.checkpoint(body)
    unroll = min(SCAN_UNROLL, cfg.n_layers) if SCAN_UNROLL else 1
    x, _ = jax.lax.scan(body, x, params["blocks"], unroll=unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x)


def loss_fn(cfg: ArchConfig, params: dict, batch: dict,
            remat: bool = True) -> tuple[jax.Array, dict]:
    logits = forward(cfg, params,
                     tokens=batch.get("tokens"),
                     embeds=batch.get("embeds"), remat=remat)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None],
                             axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = ((lse - ll) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll, {"loss": nll, "tokens": mask.sum()}


# --------------------------------------------------------------- decode -----
class DecodeState(NamedTuple):
    cache: Any          # per-family pytree, leaves stacked [L, ...]
    pos: jax.Array      # [] int32 absolute position


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int
                      ) -> DecodeState:
    dt = jnp.dtype(cfg.dtype)
    l = cfg.n_layers

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda a: jnp.zeros((l,) + a.shape, a.dtype), tree)

    cache: dict[str, Any] = {}
    if cfg.rwkv:
        cache["rwkv"] = stack(rwkv_lib.RWKVState(
            s=jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.head_dim),
                        jnp.float32),
            prev_tm=jnp.zeros((batch, cfg.d_model), dt),
            prev_cm=jnp.zeros((batch, cfg.d_model), dt)))
    else:
        kv = attn_lib.init_cache(cfg, batch, max_len, dt)
        cache["kv"] = attn_lib.KVCache(
            k=jnp.zeros((l,) + kv.k.shape, dt),
            v=jnp.zeros((l,) + kv.v.shape, dt),
            pos=jnp.zeros((l,), jnp.int32))
        if cfg.ssm_state:
            cache["ssm"] = stack(ssm_lib.SSMState(
                h=jnp.zeros((batch, cfg.n_heads, cfg.head_dim,
                             cfg.ssm_state), jnp.float32)))
    return DecodeState(cache=cache, pos=jnp.zeros((), jnp.int32))


def decode_step(cfg: ArchConfig, params: dict, state: DecodeState,
                token: jax.Array, *, max_len: int,
                embed_in: jax.Array | None = None
                ) -> tuple[jax.Array, DecodeState]:
    """One new token for every sequence. token: [B] int32 (or embed [B,D])."""
    if embed_in is not None:
        x = embed_in[:, None, :]
    else:
        x = embed_tokens(params, token[:, None])
    x = x.astype(jnp.dtype(cfg.dtype))
    pos = state.pos
    cos_full = sin_full = None
    if not cfg.rwkv:
        cos_full, sin_full = attn_lib.make_rope(cfg, max_len)

    def body(x, scanned):
        layer_params, layer_cache = scanned
        h = rms_norm(x, layer_params["ln1"], cfg.norm_eps)
        new_cache = dict(layer_cache)
        if cfg.rwkv:
            rp, rc = layer_params["rwkv"], layer_cache["rwkv"]
            y, s_new, last_tm = rwkv_lib.time_mix(cfg, rp, h, rc)
            x = x + y
            h2 = rms_norm(x, layer_params["ln2"], cfg.norm_eps)
            y2, last_cm = rwkv_lib.channel_mix(cfg, rp, h2, rc)
            x = x + y2
            new_cache["rwkv"] = rwkv_lib.RWKVState(
                s=s_new, prev_tm=last_tm, prev_cm=last_cm)
            return x, new_cache
        y, kv_new = attn_lib.decode_attention(
            cfg, layer_params["attn"], h, layer_cache["kv"], pos,
            cos_full, sin_full)
        new_cache["kv"] = kv_new
        if cfg.ssm_state:
            y_ssm, ssm_new = ssm_lib.ssm_decode(
                cfg, layer_params["ssm"], h, layer_cache["ssm"])
            y = 0.5 * (y + y_ssm)
            new_cache["ssm"] = ssm_new
        x = x + y
        h2 = rms_norm(x, layer_params["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            y2 = moe_lib.moe_apply(cfg, layer_params["moe"], h2, decode=True)
        else:
            y2 = mlp_apply(layer_params["mlp"], h2)
        return x + y2, new_cache

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], state.cache))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x)[:, 0, :]
    return logits, DecodeState(cache=new_cache, pos=pos + 1)
