"""Public model API: build a model from an ArchConfig.

``Model`` bundles the pure functions (init / loss / prefill / decode) plus
the abstract param tree and sharding specs the launcher and dry-run need.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed import sharding as shd
from repro.models import params as plib
from repro.models import transformer as tf


def arch_rules(cfg: ArchConfig, mesh) -> dict:
    """Per-arch logical->mesh rules (handles indivisible head counts)."""
    tp = mesh.shape["model"] if mesh is not None else 1
    from repro.models.attention import eff_heads
    h_eff = eff_heads(cfg)
    heads_ok = h_eff % tp == 0 and h_eff > 0
    kv_ok = cfg.n_kv_heads % tp == 0 and cfg.n_kv_heads > 0
    rules = shd.default_rules(mesh, kv_divisible=kv_ok,
                              heads_divisible=heads_ok)
    # flattened head projections (SSM / RWKV) shard if q_dim divides
    rules["heads_flat"] = "model" if cfg.q_dim % max(tp, 1) == 0 else None
    if cfg.d_ff % max(tp, 1) != 0:
        rules["mlp"] = None
    if cfg.vocab % max(tp, 1) != 0:
        rules["vocab"] = None
    return rules


@dataclasses.dataclass(frozen=True, eq=False)
class Model:
    cfg: ArchConfig
    dtype: Any

    # ----------------------------------------------------------- params -----
    def param_defs(self) -> dict:
        return tf.stacked_defs(self.cfg, self.dtype)

    def init(self, key: jax.Array) -> dict:
        return plib.materialize(key, self.param_defs())

    def abstract_params(self) -> dict:
        return plib.abstract(self.param_defs())

    def param_specs(self) -> dict:
        return plib.spec_tree(self.param_defs())

    def param_count(self) -> int:
        return plib.count(self.param_defs())

    def active_param_count(self) -> int:
        """Per-token touched params (MoE experts scaled by top_k/E)."""
        defs = self.param_defs()
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                defs, is_leaf=lambda x: isinstance(x, plib.ParamDef))[0]:
            n = int(np.prod(leaf.shape))
            keys = [getattr(k, "key", str(k)) for k in path]
            if self.cfg.moe is not None and any(
                    k in ("wg", "wu", "wd") for k in keys):
                n = n * self.cfg.moe.top_k // self.cfg.moe.num_experts
            total += n
        return total

    # ---------------------------------------------------------- training ----
    def loss(self, params: dict, batch: dict, remat: bool = True):
        return tf.loss_fn(self.cfg, params, batch, remat=remat)

    # ----------------------------------------------------------- serving ----
    def prefill(self, params: dict, batch: dict) -> jax.Array:
        return tf.forward(self.cfg, params, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"), remat=False)

    def init_decode_state(self, batch: int, max_len: int) -> tf.DecodeState:
        return tf.init_decode_state(self.cfg, batch, max_len)

    def decode_step(self, params, state, token, *, max_len: int,
                    embed_in=None):
        return tf.decode_step(self.cfg, params, state, token,
                              max_len=max_len, embed_in=embed_in)


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg=cfg, dtype=jnp.dtype(cfg.dtype))


# ------------------------------------------------------------ input specs ---
def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStructs for every model input of a dry-run cell.

    The modality frontends of [audio]/[vlm] archs are STUBS: their
    ``embeds`` input stands in for precomputed EnCodec-frame / vision-patch
    embeddings, per the assignment. ``decode`` cells describe ONE new token
    against a seq_len-deep cache.
    """
    b, s = cell.global_batch, cell.seq_len
    stub_frontend = cfg.frontend != "none"
    if cell.kind in ("train", "prefill"):
        if stub_frontend:
            specs = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                    jnp.dtype(cfg.dtype))}
        else:
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cell.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return specs
    # decode: one token (or one stub embedding) per sequence
    if stub_frontend:
        return {"embed_in": jax.ShapeDtypeStruct((b, cfg.d_model),
                                                 jnp.dtype(cfg.dtype))}
    return {"token": jax.ShapeDtypeStruct((b,), jnp.int32)}


def input_spec_shardings(cfg: ArchConfig, cell: ShapeCell, mesh) -> dict:
    """NamedShardings matching input_specs under the current rules."""
    from jax.sharding import NamedSharding
    with shd.use_mesh(mesh, arch_rules(cfg, mesh)):
        def spec_for(name):
            if name in ("tokens", "labels"):
                return shd.logical_to_spec(("batch", None))
            if name == "embeds":
                return shd.logical_to_spec(("batch", None, None))
            if name == "token":
                return shd.logical_to_spec(("batch",))
            if name == "embed_in":
                return shd.logical_to_spec(("batch", None))
            raise KeyError(name)

        specs = input_specs(cfg, cell)
        return {name: NamedSharding(
            mesh, shd.fit_spec(mesh, specs[name].shape, spec_for(name)))
            for name in specs}


def make_batch(cfg: ArchConfig, cell: ShapeCell, key: jax.Array) -> dict:
    """Concrete random batch matching input_specs (smoke tests)."""
    specs = input_specs(cfg, cell)
    out = {}
    for name, sds in specs.items():
        if sds.dtype == jnp.int32:
            out[name] = jax.random.randint(key, sds.shape, 0, cfg.vocab,
                                           jnp.int32)
        else:
            out[name] = jax.random.normal(key, sds.shape, jnp.float32) \
                .astype(sds.dtype)
    return out
