"""Abstract parameter definitions.

Models declare their parameters as a pytree of ``ParamDef`` (shape + dtype +
logical sharding axes + initializer). The same tree serves three consumers:

  * ``materialize`` — real initialization for CPU smoke tests / examples;
  * ``abstract``    — ShapeDtypeStructs for the dry-run (no allocation);
  * ``shardings``   — NamedShardings for pjit in/out specs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import logical_to_spec


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical_axes: tuple[str | None, ...]
    dtype: Any = jnp.float32
    init: str = "normal"       # normal | zeros | ones | embed
    scale: float | None = None  # None => fan-in

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (
            self.shape, self.logical_axes)


def _init_one(key, d: ParamDef):
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    scale = d.scale
    if scale is None:
        fan_in = d.shape[0] if len(d.shape) >= 2 else max(d.shape[0], 1)
        if len(d.shape) >= 3:  # stacked [L, in, out] or [E, in, out]
            fan_in = d.shape[-2]
        scale = 1.0 / math.sqrt(fan_in)
    if d.init == "embed":
        scale = 1.0
    return (scale * jax.random.normal(key, d.shape, jnp.float32)).astype(
        d.dtype)


def materialize(key: jax.Array, defs: Any) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, d) for k, d in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract(defs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def spec_tree(defs: Any) -> Any:
    """Pytree of PartitionSpec mirroring the param tree (uses current rules)."""
    return jax.tree_util.tree_map(
        lambda d: logical_to_spec(d.logical_axes),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def count(defs: Any) -> int:
    return sum(int(np.prod(d.shape)) for d in jax.tree_util.tree_leaves(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)))


def bytes_of(defs: Any) -> int:
    return sum(int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize
               for d in jax.tree_util.tree_leaves(
                   defs, is_leaf=lambda x: isinstance(x, ParamDef)))
