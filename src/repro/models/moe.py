"""Mixture-of-Experts FFN with expert parallelism.

Three execution paths, one math:

  * ``moe_ref``        — dense masked reference (every expert on every token,
                         weighted by the routing mask). O(E/topk) extra FLOPs;
                         used for correctness tests and tiny smoke configs.
  * ``moe_apply`` a2a  — production path, shard_map over the mesh: tokens
                         (sharded batch x seq) are routed with a fixed-capacity
                         all_to_all along the ``model`` (expert) axis, computed
                         with ``lax.ragged_dot`` on the owning shard, and
                         returned. Matches DeepSeek/Moonlight-style EP on TPU.
  * ``moe_apply`` repl — decode path: tokens replicated over the expert axis,
                         each shard computes only its own experts' pairs and
                         the combine is a psum. (batch 128 cannot shard over
                         the model axis, so a2a dispatch would be degenerate.)

Routing: softmax gate, top-k, renormalized top-k weights (Moonlight/Kimi
convention). Overflowing tokens beyond the capacity factor are dropped
(weight zero), the standard TPU fixed-shape compromise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.sharding import current_mesh
from repro.distributed.sharding import axis_size as shd_axis_size
from repro.models.params import ParamDef


def moe_defs(cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    e = cfg.moe
    return {
        "router": ParamDef((d, e.num_experts), (None, None), dtype,
                           scale=0.02),
        "wg": ParamDef((e.num_experts, d, e.expert_d_ff),
                       ("experts", "fsdp", None), dtype),
        "wu": ParamDef((e.num_experts, d, e.expert_d_ff),
                       ("experts", "fsdp", None), dtype),
        "wd": ParamDef((e.num_experts, e.expert_d_ff, d),
                       ("experts", None, "fsdp"), dtype),
    }


def _route(cfg: ArchConfig, router_w: jax.Array, x: jax.Array):
    """x: [T, D] -> (top-k ids [T,k], renormalized weights [T,k])."""
    gates = jax.nn.softmax(
        (x @ router_w.astype(x.dtype)).astype(jnp.float32), axis=-1)
    top_w, top_i = jax.lax.top_k(gates, cfg.moe.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    return top_i.astype(jnp.int32), top_w.astype(x.dtype)


def moe_ref(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    """Dense reference. x: [B, S, D]."""
    b, s, d = x.shape
    e = cfg.moe
    xt = x.reshape(-1, d)
    top_i, top_w = _route(cfg, p["router"], xt)
    # mask[t, ex] = combined weight of expert ex for token t
    mask = jnp.zeros((xt.shape[0], e.num_experts), x.dtype)
    mask = mask.at[jnp.arange(xt.shape[0])[:, None], top_i].add(top_w)
    h = jnp.einsum("td,edf->tef", xt, p["wg"])
    u = jnp.einsum("td,edf->tef", xt, p["wu"])
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, p["wd"])
    return jnp.einsum("ted,te->td", y, mask).reshape(b, s, d)


# ------------------------------------------------------------------ EP ------
def _expert_ffn_ragged(wg, wu, wd, x_sorted, group_sizes):
    h = jax.lax.ragged_dot(x_sorted, wg, group_sizes)
    u = jax.lax.ragged_dot(x_sorted, wu, group_sizes)
    return jax.lax.ragged_dot(jax.nn.silu(h) * u, wd, group_sizes)


def _dispatch_local(cfg, x_flat, top_i, top_w, ep, e_local, capacity):
    """Slot assignment for fixed-capacity dispatch. Returns buffers+plan."""
    t_loc, d = x_flat.shape
    k = cfg.moe.top_k
    pair_tok = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), k)  # [P]
    pair_exp = top_i.reshape(-1)                                   # [P]
    pair_w = top_w.reshape(-1)
    pair_dest = pair_exp // e_local                                # dest shard
    order = jnp.argsort(pair_dest, stable=True)
    sdest = pair_dest[order]
    counts = jnp.bincount(pair_dest, length=ep)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(sdest.shape[0], dtype=jnp.int32) - starts[sdest]
    ok = rank < capacity
    slot_d = jnp.where(ok, sdest, 0)
    slot_c = jnp.where(ok, rank, 0)
    # scatter tokens + metadata into the send buffers (drop overflow)
    buf = jnp.zeros((ep, capacity, d), x_flat.dtype)
    meta = jnp.zeros((ep, capacity), jnp.int32)          # local expert id
    src_tok = pair_tok[order]
    buf = buf.at[slot_d, slot_c].set(
        jnp.where(ok[:, None], x_flat[src_tok], 0.0))
    meta = meta.at[slot_d, slot_c].set(
        jnp.where(ok, pair_exp[order] % e_local, 0))
    # plan for the combine: where each (token,k) pair's result lives
    plan = {
        "dest": slot_d, "slot": slot_c, "tok": src_tok,
        "w": jnp.where(ok, pair_w[order], 0.0),
    }
    return buf, meta, plan


def _moe_shard_a2a(cfg, ep_axis):
    """Build the per-shard function for the sharded-tokens (a2a) path."""
    e = cfg.moe

    def fn(router_w, wg, wu, wd, x):
        b, s, d = x.shape
        x_flat = x.reshape(-1, d)
        t_loc = x_flat.shape[0]
        ep = shd_axis_size(ep_axis)
        e_local = e.num_experts // ep
        capacity = max(e.top_k, int(t_loc * e.top_k / ep
                                    * e.capacity_factor))
        top_i, top_w = _route(cfg, router_w, x_flat)
        buf, meta, plan = _dispatch_local(cfg, x_flat, top_i, top_w, ep,
                                          e_local, capacity)
        # exchange: row d of buf goes to shard d; we receive rows from all
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                                 tiled=True)
        meta = jax.lax.all_to_all(meta, ep_axis, split_axis=0, concat_axis=0,
                                  tiled=True)
        recv = buf.reshape(-1, d)                 # [ep*capacity, D]
        ids = meta.reshape(-1)
        order = jnp.argsort(ids, stable=True)
        x_sorted = recv[order]
        group_sizes = jnp.bincount(ids, length=e_local)
        y_sorted = _expert_ffn_ragged(wg, wu, wd, x_sorted, group_sizes)
        y = jnp.zeros_like(y_sorted).at[order].set(y_sorted)
        y = y.reshape(ep, capacity, d)
        y = jax.lax.all_to_all(y, ep_axis, split_axis=0, concat_axis=0,
                               tiled=True)
        # combine on the source shard
        vals = y[plan["dest"], plan["slot"]] * plan["w"][:, None]
        out = jax.ops.segment_sum(vals, plan["tok"], num_segments=t_loc)
        return out.reshape(b, s, d).astype(x.dtype)

    return fn


def _moe_shard_repl(cfg, ep_axis):
    """Per-shard function for the replicated-tokens (decode) path."""
    e = cfg.moe

    def fn(router_w, wg, wu, wd, x):
        b, s, d = x.shape
        x_flat = x.reshape(-1, d)
        t_loc = x_flat.shape[0]
        ep = shd_axis_size(ep_axis)
        e_local = e.num_experts // ep
        my = jax.lax.axis_index(ep_axis)
        top_i, top_w = _route(cfg, router_w, x_flat)
        pair_tok = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32),
                              e.top_k)
        pair_exp = top_i.reshape(-1)
        pair_w = top_w.reshape(-1)
        mine = (pair_exp // e_local) == my
        local_id = jnp.where(mine, pair_exp % e_local, e_local - 1)
        w = jnp.where(mine, pair_w, 0.0)
        order = jnp.argsort(local_id, stable=True)
        x_sorted = x_flat[pair_tok[order]]
        # non-mine pairs were binned into expert e_local-1; they compute but
        # combine with weight zero (fixed-shape compromise, same as capacity)
        group_sizes = jnp.bincount(local_id, length=e_local)
        y_sorted = _expert_ffn_ragged(wg, wu, wd, x_sorted, group_sizes)
        vals = y_sorted * w[order][:, None]
        out = jax.ops.segment_sum(vals, pair_tok[order],
                                  num_segments=t_loc)
        out = jax.lax.psum(out, ep_axis)
        return out.reshape(b, s, d).astype(x.dtype)

    return fn


def moe_apply(cfg: ArchConfig, p: dict, x: jax.Array, *,
              decode: bool = False) -> jax.Array:
    """Dispatching MoE entry point. x: [B, S, D].

    Uses the ambient (possibly partially-manual) mesh: when called inside the
    consensus trainer's pod-manual region, only the still-auto data/model
    axes are mapped here; standalone, it maps batch axes too.
    """
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names \
            or mesh.shape["model"] == 1 \
            or cfg.moe.num_experts % mesh.shape["model"] != 0:
        return moe_ref(cfg, p, x)

    from repro.distributed.sharding import abstract_mesh
    abstract = abstract_mesh()
    if abstract is not None and abstract.shape_tuple:
        manual_already = {name for name, ty in
                          zip(abstract.axis_names, abstract.axis_types)
                          if str(ty) == "Manual"}
        run_mesh = abstract
    else:
        manual_already = set()
        run_mesh = mesh

    from repro.distributed.sharding import logical_to_spec
    batch_rule = logical_to_spec(("batch",))[0] or ()
    if isinstance(batch_rule, str):
        batch_rule = (batch_rule,)
    batch_axes = tuple(a for a in batch_rule if a not in manual_already)

    if decode:
        x_spec = P(batch_axes if batch_axes else None, None, None)
        fn = _moe_shard_repl(cfg, "model")
        out_spec = x_spec
    else:
        x_spec = P(batch_axes if batch_axes else None, "model", None)
        fn = _moe_shard_a2a(cfg, "model")
        out_spec = x_spec
    w_spec = P("model", None, None)
    # manual over ALL remaining mesh axes: jax.grad of a shard_map that is
    # manual over a strict subset of axes miscompiles in XLA
    # (hlo_instruction.cc "Invalid binary instruction opcode copy");
    # axes not used in specs are simply replicated-manual.
    axis_names = set(run_mesh.axis_names) - manual_already
    from repro.distributed.sharding import shard_map_compat
    return shard_map_compat(
        fn, run_mesh,
        in_specs=(P(None, None), w_spec, w_spec, w_spec, x_spec),
        out_specs=out_spec,
        manual_axes=axis_names,
    )(p["router"], p["wg"], p["wu"], p["wd"], x)
