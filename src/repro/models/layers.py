"""Shared building blocks: norms, RoPE, SwiGLU MLP, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models.params import ParamDef


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_table(seq_len: int, head_dim: int, theta: float,
               dtype=jnp.float32) -> tuple[jax.Array, jax.Array]:
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    pos = jnp.arange(seq_len, dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]          # [S, half]
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, S, H, hd]; cos/sin: [S, hd//2] (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[None, :, None, :]
    sin = sin[None, :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------- MLP ------
def mlp_defs(cfg: ArchConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wi_gate": ParamDef((d, f), ("fsdp", "mlp"), dtype),
        "wi_up": ParamDef((d, f), ("fsdp", "mlp"), dtype),
        "wo": ParamDef((f, d), ("mlp", "fsdp"), dtype),
    }


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    h = shard(h, "batch", None, "mlp")
    return h @ p["wo"]


# ----------------------------------------------------------- embeddings -----
def embed_defs(cfg: ArchConfig, dtype) -> dict:
    out = {"embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "fsdp"),
                             dtype, init="embed", scale=0.02)}
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamDef((cfg.d_model, cfg.vocab),
                                  ("fsdp", "vocab"), dtype)
    return out


def embed_tokens(p: dict, tokens: jax.Array) -> jax.Array:
    return shard(jnp.take(p["embed"], tokens, axis=0), "batch", None, None)


def unembed(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    return shard(x @ w, "batch", None, "vocab")
