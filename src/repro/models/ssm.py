"""Mamba2-style selective SSM head (the SSM half of Hymba blocks).

Per head h with state size N:   (discretized, dt > 0 via softplus)
    h_t = exp(-dt_t * exp(A_log)) * h_{t-1} + dt_t * (x_t outer B_t)
    y_t = h_t @ C_t + D_skip * x_t
with B_t, C_t shared across heads (n_groups=1) and a SiLU gate z.
The depthwise causal conv of Mamba is omitted (DESIGN.md §4); the paper's
technique is optimizer-level and unaffected.

Reference = lax.scan over time; the Pallas chunked kernel in repro.kernels
targets the TPU hot path for long_500k prefill.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.params import ParamDef


class SSMState(NamedTuple):
    h: jax.Array    # [B, H, hd, N]


# dry-run FLOPs-accounting knob (see transformer.SCAN_UNROLL)
TIME_UNROLL = 1


def ssm_defs(cfg: ArchConfig, dtype) -> dict:
    d, di, n, hh = cfg.d_model, cfg.q_dim, cfg.ssm_state, cfg.n_heads
    return {
        "w_x": ParamDef((d, di), ("fsdp", "heads_flat"), dtype),
        "w_z": ParamDef((d, di), ("fsdp", "heads_flat"), dtype),
        "w_b": ParamDef((d, n), ("fsdp", None), dtype),
        "w_c": ParamDef((d, n), ("fsdp", None), dtype),
        "w_dt": ParamDef((d, hh), ("fsdp", None), dtype),
        "dt_bias": ParamDef((hh,), (None,), dtype, init="zeros"),
        "a_log": ParamDef((hh,), (None,), dtype, init="zeros"),
        "d_skip": ParamDef((hh,), (None,), dtype, init="ones"),
        "w_out": ParamDef((di, d), ("heads_flat", "fsdp"), dtype),
    }


def _proj(cfg: ArchConfig, p: dict, x: jax.Array):
    b, s, _ = x.shape
    hh, hd = cfg.n_heads, cfg.head_dim
    xi = (x @ p["w_x"]).reshape(b, s, hh, hd)
    z = x @ p["w_z"]
    bt = x @ p["w_b"]                                     # [B, S, N]
    ct = x @ p["w_c"]
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # [B, S, H]
    decay = jnp.exp(-dt * jnp.exp(p["a_log"].astype(jnp.float32)))
    return xi, z, bt, ct, dt, decay


def ssm_apply(cfg: ArchConfig, p: dict, x: jax.Array,
              state: SSMState | None = None
              ) -> tuple[jax.Array, SSMState]:
    """Full-sequence scan. x: [B, S, D]. Returns (y, final state)."""
    b, s, d = x.shape
    hh, hd, n = cfg.n_heads, cfg.head_dim, cfg.ssm_state
    xi, z, bt, ct, dt, decay = _proj(cfg, p, x)
    h0 = state.h if state is not None else jnp.zeros(
        (b, hh, hd, n), jnp.float32)

    def step(h, inp):
        xt, btt, ctt, dtt, dec = inp     # [B,H,hd], [B,N], [B,N], [B,H], ...
        upd = (dtt[:, :, None] * xt)[..., None] * btt[:, None, None, :]
        h = dec[:, :, None, None] * h + upd.astype(jnp.float32)
        y = jnp.einsum("bhdn,bn->bhd", h, ctt.astype(jnp.float32))
        return h, y

    xs = (jnp.moveaxis(xi, 1, 0), jnp.moveaxis(bt, 1, 0),
          jnp.moveaxis(ct, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(decay, 1, 0))
    h_last, ys = jax.lax.scan(step, h0, xs, unroll=min(TIME_UNROLL, s))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)            # [B, S, H, hd]
    y = y + p["d_skip"][None, None, :, None] * xi
    y = (y.reshape(b, s, -1) * jax.nn.silu(z))
    return y @ p["w_out"], SSMState(h=h_last)


def ssm_decode(cfg: ArchConfig, p: dict, x: jax.Array,
               state: SSMState) -> tuple[jax.Array, SSMState]:
    """Single-token step. x: [B, 1, D]."""
    y, st = ssm_apply(cfg, p, x, state)
    return y, st
