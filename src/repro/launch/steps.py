"""Plain (single-replica-group) train / serve step builders.

These are the GSPMD-only paths: params sharded by the arch rules (FSDP on
``data``, TP/EP on ``model``), batch on (pod,)data. The consensus trainer
wraps the same local step along the pod axis; serving never needs consensus.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed import sharding as shd
from repro.models import attention as attn_lib
from repro.models.model import Model, arch_rules, input_specs
from repro.optim import adamw as adamw_lib


class PlainTrainState(NamedTuple):
    params: Any
    opt: adamw_lib.AdamWState
    step: jax.Array


def make_train_fns(model: Model, mesh, acfg: adamw_lib.AdamWConfig, *,
                   grad_rs: bool = False):
    """Returns (init_fn, step_fn, abstract_state, state_shardings).

    grad_rs: constrain gradients to the parameter sharding right at the
    value_and_grad output. XLA then reduce-scatters each gradient into its
    FSDP shard instead of all-reducing the full gradient and slicing —
    roughly halving the dominant train-step collective (§Perf).
    """
    rules = arch_rules(model.cfg, mesh)

    def init_fn(key):
        with shd.use_mesh(mesh, rules):
            params = model.init(key)
        return PlainTrainState(params=params,
                               opt=adamw_lib.init(acfg, params),
                               step=jnp.zeros((), jnp.int32))

    def step_fn(state: PlainTrainState, batch):
        with shd.use_mesh(mesh, rules):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch), has_aux=True)(state.params)
            if grad_rs and mesh is not None:
                pspec = model.param_specs()
                grads = jax.tree_util.tree_map(
                    lambda g, s: jax.lax.with_sharding_constraint(
                        g, NamedSharding(mesh, s)),
                    grads, pspec)
            params, opt, m = adamw_lib.update(acfg, state.opt, state.params,
                                              grads)
        new = PlainTrainState(params=params, opt=opt, step=state.step + 1)
        return new, {"loss": loss, **m}

    def abstract_state():
        ap = model.abstract_params()
        return PlainTrainState(params=ap,
                               opt=adamw_lib.abstract_state(acfg, ap),
                               step=jax.ShapeDtypeStruct((), jnp.int32))

    def state_shardings():
        with shd.use_mesh(mesh, rules):
            pspec = model.param_specs()
        ap = model.abstract_params()
        to_ns = lambda tree: jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda s: isinstance(s, P))
        params_sh = to_ns(pspec)
        rep = NamedSharding(mesh, P())
        if acfg.factored:
            def fv(s, p):
                # mirror adamw._is_factorable exactly (shape-based)
                s = tuple(s)
                if len(p.shape) >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1:
                    return (NamedSharding(mesh, P(*s[:-1])),
                            NamedSharding(mesh, P(*(s[:-2] + s[-1:]))))
                return NamedSharding(mesh, P(*s))
            opt_v = jax.tree_util.tree_map(
                fv, pspec, ap, is_leaf=lambda s: isinstance(s, P))
        else:
            opt_v = to_ns(pspec)
        return PlainTrainState(
            params=params_sh,
            opt=adamw_lib.AdamWState(step=rep, m=to_ns(pspec), v=opt_v),
            step=rep)

    return init_fn, step_fn, abstract_state, state_shardings


# ------------------------------------------------------------- serving ------
def decode_state_specs(cfg: ArchConfig, mesh, batch: int, max_len: int):
    """(abstract decode state, shardings) under the arch rules."""
    from repro.models import transformer as tf
    rules = arch_rules(cfg, mesh)
    tp = mesh.shape["model"]
    kv_on_heads = cfg.n_kv_heads % tp == 0 and not cfg.sliding_window

    def shape_of(tree):
        return jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)

    with shd.use_mesh(mesh, rules):
        state = jax.eval_shape(
            lambda: tf.init_decode_state(cfg, batch, max_len))

    batch_axes = rules["batch"]

    def spec_for_leaf(path_keys, leaf):
        nd = len(leaf.shape)
        name = path_keys[-1] if path_keys else ""
        if name in ("k", "v"):            # KV cache [L, B, S, K, hd]
            if kv_on_heads:
                return P(None, batch_axes, None, "model", None)
            return P(None, batch_axes, "model", None, None)
        if name == "s":                   # rwkv state [L, B, H, hd, hd]
            if cfg.n_heads % tp == 0:
                return P(None, batch_axes, "model", None, None)
            return P(None, batch_axes, None, "model", None)
        if name == "h":                   # ssm state [L, B, H, hd, N]
            if cfg.n_heads % tp == 0:
                return P(None, batch_axes, "model", None, None)
            return P(None, batch_axes, None, "model", None)
        if name in ("prev_tm", "prev_cm"):  # [L, B, D]
            return P(None, batch_axes, None)
        return P(*([None] * nd))

    flat, tdef = jax.tree_util.tree_flatten_with_path(state)
    specs = []
    for path, leaf in flat:
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        spec = shd.fit_spec(mesh, leaf.shape, spec_for_leaf(keys, leaf))
        specs.append(NamedSharding(mesh, spec))
    shardings = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(state), specs)
    return state, shardings


def make_serve_fns(model: Model, mesh, cell: ShapeCell):
    """Returns (prefill_fn, decode_fn) closed over the arch rules."""
    rules = arch_rules(model.cfg, mesh)

    def prefill_fn(params, batch):
        with shd.use_mesh(mesh, rules):
            return model.prefill(params, batch)

    def decode_fn(params, state, inputs):
        with shd.use_mesh(mesh, rules):
            return model.decode_step(
                params, state, inputs.get("token"),
                max_len=cell.seq_len, embed_in=inputs.get("embed_in"))

    return prefill_fn, decode_fn
