"""Serving launcher: batched prefill + greedy decode.

CPU-scale demo on reduced configs; the dry-run exercises the full-size
decode_32k / long_500k cells on the production meshes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.launch.mesh import make_debug_mesh
from repro.models import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced_config(args.arch) if args.reduced \
        else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.gen_len

    key = jax.random.PRNGKey(args.seed + 1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab, jnp.int32)

    # prefill: run full-sequence forward, take last-position logits
    t0 = time.time()
    if cfg.frontend != "none":
        embeds = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model), jnp.float32)
        logits = model.prefill(params, {"embeds": embeds})
    else:
        logits = model.prefill(params, {"tokens": prompts})
    next_tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
    t_prefill = time.time() - t0

    # replay prompt through the decode cache, then generate
    state = model.init_decode_state(args.batch, max_len)
    step = jax.jit(lambda p, s, t: model.decode_step(p, s, t,
                                                     max_len=max_len))
    emb_step = jax.jit(lambda p, s, e: model.decode_step(
        p, s, None, max_len=max_len, embed_in=e))
    if cfg.frontend != "none":
        for i in range(args.prompt_len):
            lg, state = emb_step(params, state, embeds[:, i, :])
    else:
        for i in range(args.prompt_len):
            lg, state = step(params, state, prompts[:, i])
    next_tok = jnp.argmax(lg, -1).astype(jnp.int32)

    generated = [next_tok]
    t0 = time.time()
    for _ in range(args.gen_len - 1):
        if cfg.frontend != "none":
            # frontend stubs decode from the token embedding table is absent;
            # feed the argmax token through a random embedding (demo only)
            emb = jax.random.normal(jax.random.fold_in(key, int(
                np.asarray(next_tok)[0])), (args.batch, cfg.d_model))
            lg, state = emb_step(params, state, emb)
        else:
            lg, state = step(params, state, next_tok)
        next_tok = jnp.argmax(lg, -1).astype(jnp.int32)
        generated.append(next_tok)
    jax.block_until_ready(next_tok)
    t_decode = time.time() - t0
    toks = np.stack([np.asarray(t) for t in generated], 1)
    print(f"arch={cfg.arch_id} batch={args.batch} "
          f"prefill={t_prefill*1e3:.0f}ms "
          f"decode={t_decode / max(args.gen_len - 1, 1) * 1e3:.1f}ms/tok")
    print("sample generations (token ids):")
    for row in toks[:2]:
        print("  ", row[:16].tolist())
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
