"""Training launcher: consensus-ADMM distributed training end to end.

CPU-scale demo / integration entry (reduced configs); identical code path on
real TPU — only the mesh and config sizes change.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --reduced \\
      --steps 40 --scheme nap --topology ring --local-steps 4 \\
      --ckpt-dir /tmp/ckpt
Resume is automatic if the checkpoint dir has state.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.async_exec import (AsyncConfig, AsyncExecutor, RoundClock,
                              straggler_compute)
from repro.checkpoint import latest_steps, restore, save_async, wait_pending
from repro.configs import get_config, get_reduced_config
from repro.core.penalty import PenaltyConfig, SCHEMES
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import (make_debug_mesh, make_production_mesh,
                               set_backend_flags)
from repro.models import build_model
from repro.obs import ObsConfig, ObsWriter, host_span_factory
from repro.optim import ConsensusConfig, ConsensusTrainer
from repro.optim.adamw import AdamWConfig
from repro.runtime import (ElasticController, RetryPolicy, StragglerMonitor,
                           aged_out_nodes, with_retries)
from repro.topology import SCHEDULERS as TOPO_SCHEDULERS, TopologyConfig


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--batch-per-node", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--mesh", choices=["debug", "prod", "none"],
                    default="debug")
    ap.add_argument("--multi-pod", action="store_true", default=True)
    ap.add_argument("--scheme", choices=SCHEMES, default="nap")
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--topo-scheduler", choices=TOPO_SCHEDULERS,
                    default="static",
                    help="dynamic-topology edge scheduler (repro.topology)")
    ap.add_argument("--topo-churn", action="store_true",
                    help="compile the churn offset superset so node drops "
                         "are layout-preserving (no recompilation)")
    ap.add_argument("--drop-node", default="",
                    help="STEP:VICTIM — simulate losing pod VICTIM after "
                         "STEP (debug-mesh churn drill; implies --topo-churn)")
    ap.add_argument("--drop-stragglers", action="store_true",
                    help="ghost a flagged straggler pod via the topology "
                         "runtime instead of just logging it (async mode "
                         "flags by edge age, sync mode by wall-clock EMA)")
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="bounded-staleness executor (repro.async_exec): "
                         "consensus rounds consume the freshest LANDED "
                         "payload per edge instead of barriering")
    ap.add_argument("--max-staleness", type=int, default=2,
                    help="async: rounds a consumed payload may lag; older "
                         "edges gate until a fresh payload lands (0 = "
                         "wait for everything, bit-identical to sync)")
    ap.add_argument("--slow-node", default="",
                    help="async drill: NODE:FACTOR — model pod NODE taking "
                         "FACTOR x the fleet round time (e.g. 0:2.0)")
    ap.add_argument("--shard-consensus", action="store_true",
                    help="shard the flat consensus state (lam, neighbor "
                         "mean, wire/ledger rows) over the in-pod mesh "
                         "axes: per-device consensus-state HBM shrinks by "
                         "the in-pod axis size (docs/consensus_engine.md)")
    ap.add_argument("--pipeline-offsets", type=int, default=1,
                    help="round pipeline depth: how many graph offsets may "
                         "have their collective-permute in flight while "
                         "earlier offsets decode/probe/fuse (1 = today's "
                         "sequential loop, bit-identical at every depth; "
                         "docs/consensus_engine.md \"Round pipeline\")")
    ap.add_argument("--no-async-collectives", action="store_true",
                    help="skip arming the XLA latency-hiding/async-stream "
                         "flags (set_backend_flags) before jax init; the "
                         "pipeline still reorders issue/consume but the "
                         "scheduler won't hide the permutes")
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--eta0", type=float, default=0.1)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--compression", default="none",
                    help="legacy spelling of --wire-codec (none | int8)")
    ap.add_argument("--wire-codec", default="",
                    choices=["", "native", "int8", "fp8_e4m3", "fp8_e5m2"],
                    help="consensus wire codec (repro.wire): native = "
                         "params dtype, int8 = absmax per leaf + bitcast "
                         "scale tail, fp8_* = 1 B/param float8 with "
                         "per-block f32 scales; empty resolves from "
                         "--compression")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs-dir", default="",
                    help="observability (repro.obs): drain the on-device "
                         "metrics ring + topology event journal into this "
                         "directory (metrics.jsonl / events.jsonl / "
                         "rollup.json; async runs add the RoundClock "
                         "Perfetto trace). Unset = obs fully off — the "
                         "compiled step is byte-identical")
    ap.add_argument("--obs-ring-cap", type=int, default=256,
                    help="rows in the on-device metrics ring")
    ap.add_argument("--obs-drain-every", type=int, default=8,
                    help="host drain cadence in consensus rounds")
    ap.add_argument("--no-node-ring", action="store_true",
                    help="compile out the per-node telemetry ring "
                         "(obs.node_ring), keeping only the scalar ring")
    ap.add_argument("--health", action="store_true",
                    help="run the online health monitor (repro.obs.health) "
                         "over drained per-node rows: health_* events in "
                         "the journal, a per-node score table + advisory "
                         "recommendations in the rollup and printed at "
                         "exit. ADVISORY ONLY — nothing acts on it. "
                         "Requires --obs-dir")
    ap.add_argument("--profile-rounds", type=int, default=0,
                    help="capture a jax profiler trace covering the first "
                         "N consensus rounds into <obs-dir>/profile "
                         "(view in Perfetto/TensorBoard; the obs trace "
                         "spans label the round phases)")
    args = ap.parse_args(argv)
    if args.health and not args.obs_dir:
        ap.error("--health requires --obs-dir (the monitor feeds off "
                 "drained per-node telemetry)")
    return args


def main(argv=None):
    args = parse_args(argv)
    if not args.no_async_collectives:
        # must land before the first jax device touch (build_model / mesh
        # construction below) — a warn-no-op afterwards
        set_backend_flags(async_collectives=True)
    cfg = get_reduced_config(args.arch) if args.reduced \
        else get_config(args.arch)
    model = build_model(cfg)
    if args.mesh == "prod":
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    elif args.mesh == "debug":
        mesh = make_debug_mesh(multi_pod=args.multi_pod)
    else:
        mesh = None

    drop_at, drop_victim = (-1, -1)
    if args.drop_node:
        drop_at, drop_victim = (int(x) for x in args.drop_node.split(":"))
    churn = args.topo_churn or args.drop_stragglers or drop_at >= 0
    topo_sched = args.topo_scheduler
    if args.async_mode and topo_sched == "static" and args.max_staleness > 0:
        # the stale scheduler mirrors the executor's in-round gating into
        # the topology mask (monitoring + wire accounting see it)
        topo_sched = "stale"
    obs_cfg = ObsConfig(ring_capacity=args.obs_ring_cap,
                        drain_every=args.obs_drain_every,
                        with_node_ring=not args.no_node_ring) \
        if args.obs_dir else None
    trainer = ConsensusTrainer(
        model, mesh,
        adamw=AdamWConfig(lr=args.lr),
        consensus=ConsensusConfig(
            penalty=PenaltyConfig(scheme=args.scheme, eta0=args.eta0),
            topology=args.topology, local_steps=args.local_steps,
            compression=args.compression,
            wire_codec=args.wire_codec,
            shard_consensus=args.shard_consensus,
            pipeline_offsets=args.pipeline_offsets,
            dyn_topology=TopologyConfig(scheduler=topo_sched, churn=churn,
                                        max_staleness=args.max_staleness),
            async_exec=(AsyncConfig(max_staleness=args.max_staleness)
                        if args.async_mode else None),
            obs=obs_cfg))
    state = trainer.init_state(jax.random.PRNGKey(args.seed))
    start_step = 0
    if args.ckpt_dir and latest_steps(args.ckpt_dir):
        state, meta = restore(args.ckpt_dir, state)
        start_step = int(meta["step"])
        print(f"resumed from step {start_step}")

    data = SyntheticTokens(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq,
        batch_per_node=args.batch_per_node,
        num_nodes=trainer.num_nodes, seed=args.seed))

    # local step stays undonated: with_retries may replay it with the same
    # state buffers; the consensus round is never retried, so donate there.
    train = jax.jit(trainer.train_step)
    _, cons = trainer.jit_step_fns()
    executor = None
    if args.async_mode and trainer.num_nodes > 1:
        compute = np.ones(trainer.num_nodes)
        if args.slow_node:
            v, f = args.slow_node.split(":")
            compute = straggler_compute(trainer.num_nodes, victim=int(v),
                                        factor=float(f))
        executor = AsyncExecutor(trainer, RoundClock(
            compute_s=compute, wire_s=0.25,
            offsets=tuple(trainer.offsets)))
    monitor = StragglerMonitor(trainer.num_nodes)
    elastic = ElasticController(trainer.graph, topology=trainer.topo_rt)
    step_fn = with_retries(lambda s, b: train(s, b), RetryPolicy())

    writer = None
    if args.obs_dir:
        writer = ObsWriter(args.obs_dir, meta={
            "arch": cfg.arch_id, "scheme": args.scheme,
            "topology": args.topology, "num_nodes": trainer.num_nodes,
            "wire_codec": trainer.codec_name,
            "wire_bytes_per_round":
                trainer.codec.wire_bytes() * max(len(trainer.offsets), 1),
            "offsets": [int(o) for o in trainer.offsets],
            "async": bool(args.async_mode),
            "ring_capacity": args.obs_ring_cap,
            "drain_every": args.obs_drain_every,
        }, max_staleness=(args.max_staleness if args.async_mode else None),
            health=args.health)
    round_span = host_span_factory(writer is not None)
    rounds, profiling = 0, False

    def make_batch(step):
        if cfg.frontend != "none":
            return data.embeds_batch(step, cfg.d_model)
        return data.batch(step)

    t_start = time.time()
    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = make_batch(step)
        state, m = step_fn(state, batch)
        jax.block_until_ready(m["loss"])
        dt = time.time() - t0
        slow = monitor.observe(np.full(trainer.num_nodes, dt))
        line = f"step {step:5d} loss {float(m['loss']):.4f} {dt*1e3:.0f}ms"
        if trainer.should_sync(step):
            probe = make_batch(10**6 + step)
            if args.profile_rounds > 0 and rounds == 0 and not profiling:
                try:
                    jax.profiler.start_trace(
                        os.path.join(args.obs_dir or ".", "profile"))
                    profiling = True
                except Exception as e:  # profiler backend unavailable
                    print(f"profiler unavailable: {e}", flush=True)
            with round_span("round/async" if executor is not None
                            else "round/sync"):
                if executor is not None:
                    state, cm = executor.consensus_round(state, probe)
                else:
                    state, cm = cons(state, probe)
            rounds += 1
            if profiling and rounds >= args.profile_rounds:
                jax.block_until_ready(cm["r_max"])
                jax.profiler.stop_trace()
                profiling = False
                print(f"profile trace ({args.profile_rounds} rounds) -> "
                      f"{os.path.join(args.obs_dir or '.', 'profile')}",
                      flush=True)
            if writer is not None and rounds % args.obs_drain_every == 0:
                writer.drain(state, step=step + 1)
            line += (f" | consensus r={float(cm['r_max']):.4f} "
                     f"eta={float(cm['eta_mean']):.4f}")
            if trainer.dynamic:
                line += f" active={float(cm['active_edges']):.2f}"
            if executor is not None and "stale_edges" in cm:
                line += (f" stale={float(cm['stale_edges']):.2f}"
                         f" age_max={int(cm['age_max'])}")
            if executor is not None and args.drop_stragglers:
                # async unification: the staleness clocks ARE the
                # straggler signal — wall-clock EMA not needed
                for v in aged_out_nodes(
                        state.topo, max_staleness=args.max_staleness):
                    alive = np.asarray(state.topo.node_alive)
                    if alive[v] and alive.sum() > 2:
                        state = state._replace(topo=elastic.drop_preserving(
                            v, state.topo, step))
                        line += f" | ghosted aged-out node {v}"
        if step == drop_at:
            # layout-preserving churn drill: ghost the victim, keep going —
            # same compiled step fns, no restart (a topology epoch)
            state = state._replace(topo=elastic.drop_preserving(
                drop_victim, state.topo, step))
            line += f" | dropped node {drop_victim} (topology epoch)"
        if slow and executor is None:
            line += f" | stragglers: {slow}"
            if args.drop_stragglers and trainer.dynamic:
                for v in slow:
                    # re-read liveness each drop: several stragglers may be
                    # flagged in one step and the >2-survivors floor must
                    # see the drops already applied
                    alive = np.asarray(state.topo.node_alive)
                    if alive[v] and alive.sum() > 2:
                        state = state._replace(topo=elastic.drop_preserving(
                            v, state.topo, step))
                        line += f" | ghosted straggler {v}"
        print(line, flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_async(args.ckpt_dir, step + 1, state,
                       metadata={"step": step + 1, "arch": cfg.arch_id,
                                 "scheme": args.scheme,
                                 "topology": args.topology})
    wait_pending()
    print(f"done: {args.steps - start_step} steps in "
          f"{time.time() - t_start:.1f}s")
    if executor is not None:
        print(f"async executor: {executor.summary()}")
    if writer is not None:
        writer.drain(state, step=args.steps)          # tail < drain_every
        if executor is not None:
            writer.observe_executor(executor.summary())
            executor.export_timeline(
                os.path.join(args.obs_dir, "roundclock_trace.json"))
        rollup = writer.finalize(
            extra=({"async_summary": executor.summary()}
                   if executor is not None else None))
        print(f"obs: {rollup['rounds']} rounds, "
              f"{rollup['journal_events']} topology events, "
              f"{rollup['dropped_rows']} dropped rows -> {args.obs_dir}")
        if args.health and "health" in rollup:
            h = rollup["health"]
            print("health scores (1.0 = clean):")
            for n in h["nodes"]:
                active = [k for k in ("divergence", "eta_stall",
                                      "eta_oscillation", "straggler",
                                      "drift") if n.get(k)]
                tag = f" [{', '.join(active)}]" if active else ""
                print(f"  node {n['node']}: {n['score']:.2f}{tag}")
            recs = h["recommendations"]
            for note in recs["notes"]:
                print(f"  advisory: {note}")
            if not recs["notes"]:
                print("  no advisories")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
