from repro.launch.mesh import backend_initialized, set_backend_flags
if not backend_initialized():
    set_backend_flags(async_collectives=True, host_device_count=512)
# The lines above MUST run before anything touches a jax backend (jax
# locks XLA_FLAGS — including the fake host device count — at first init).
# mesh.py deliberately imports cleanly without initializing a backend, and
# set_backend_flags appends to a user-set XLA_FLAGS instead of clobbering
# it. The guard keeps library imports of this module (benchmarks, tests —
# typically after jax is already up) from warning about locked-in flags.
# Everything below may now use jax freely.

import argparse
import json
import os
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cells, get_config
from repro.configs.base import ArchConfig, ShapeCell
from repro.core.penalty import PenaltyConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.steps import decode_state_specs, make_serve_fns, \
    make_train_fns
from repro.models import (build_model, arch_rules, input_specs,
                          input_spec_shardings)
from repro.models.model import Model
from repro.optim import ConsensusConfig, ConsensusTrainer
from repro.optim.adamw import AdamWConfig

# match only collective op APPLICATIONS (`... = shape all-reduce(...)`),
# not operand references (`%all-reduce.12`) or fusions that consume them
_COLL_RE = re.compile(
    r"(?<!%)\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?(?:\.\d+)?\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in post-SPMD HLO.

    all-reduce counts 2x (ring = reduce-scatter + all-gather on the wire).
    Returns totals per op kind plus the weighted 'wire' total.
    """
    totals: dict[str, int] = {}
    wire = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "=" not in line:
            continue
        # result shapes appear between '=' and the op name
        lhs = line.split("=", 1)[1]
        op_pos = lhs.find(m.group(1))
        result_part = lhs[:op_pos] if op_pos >= 0 else lhs
        nbytes = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(result_part))
        kind = m.group(1)
        totals[kind] = totals.get(kind, 0) + nbytes
        wire += nbytes * (2 if kind == "all-reduce" else 1)
    totals["wire_total"] = wire
    return totals


def consensus_state_bytes(layout, *, deg: int, compression: str,
                          n_shards: int = 1,
                          with_ledger: bool = False,
                          obs_ring_cap: int = 0,
                          obs_num_nodes: int = 0) -> dict:
    """Per-DEVICE bytes of the flat consensus state.

    Counts what one device materializes for its pod's node row: the f32
    lam / theta_bar_prev flat buffers, the stacked per-offset wire rows the
    fused round streams, and (async executor) the wire-ledger rows.
    ``compression`` is any wire-codec name (``repro.wire.WIRE_CODECS``) or
    the legacy ``"none"`` spelling — all row sizes are read from the
    codec. With ``n_shards > 1`` (``ConsensusConfig.shard_consensus``)
    each device holds only its in-pod slab, so everything shrinks by ~the
    in-pod axis size — both codec tails split with the slabs: the fp8
    per-block scales exactly, the int8 per-leaf scales shard-locally (each
    shard carries only the scales of leaves its slab overlaps, padded to
    the widest shard window).
    """
    from repro import wire

    if n_shards > 1:
        slay = layout.shard(n_shards)
        flat = 4 * slay.shard_total
        wire_row = wire.get_codec(compression, layout, slay).wire_row_bytes()
    else:
        flat = 4 * layout.total
        wire_row = wire.get_codec(compression, layout).wire_bytes()
    out = {"lam": flat, "theta_bar_prev": flat,
           "wire_rows": deg * wire_row}
    if with_ledger:
        out["ledger_rows"] = deg * wire_row
    if obs_ring_cap > 0:
        # the on-device metrics ring (repro.obs): [cap, n_metrics] f32,
        # replicated — a constant, layout-independent sliver of HBM
        from repro.obs import schema as obs_schema
        out["metrics_ring"] = 4 * obs_ring_cap * obs_schema.NUM_COLUMNS
        if obs_num_nodes > 0:
            # per-node telemetry ring: [cap, J, n_node_cols] f32 — scales
            # with mesh width J but stays replicated like the scalar ring
            out["node_metrics_ring"] = (4 * obs_ring_cap * obs_num_nodes
                                        * obs_schema.NUM_NODE_COLUMNS)
    out["total"] = sum(out.values())
    return out


def fused_round_roofline(model: "Model", mesh, *, compression: str,
                         topology: str = "ring", block_size: int = 0,
                         dyn_topology=None, shard_consensus: bool = False,
                         with_ledger: bool = False,
                         obs_ring_cap: int = 0,
                         obs_drain_every: int = 8) -> dict:
    """Analytic HBM/wire model of the fused flat-buffer consensus round.

    ``compression`` is any wire-codec name (``repro.wire.WIRE_CODECS``) or
    the legacy ``"none"`` spelling; all wire volumes are read from the
    codec — no hard-coded per-format byte tables.

    The Pallas round kernel is opaque to XLA's cost analysis (and runs in
    interpret mode on CPU dry-runs), so the fused path is accounted from the
    static FlatLayout instead: per node the kernel reads theta, lam and
    bar_prev (f32), streams deg rolled wire payloads (quantized or f32), and
    writes theta, lam and bar — one logical HBM pass over the flat vector
    per operand. The naive per-leaf path is ~2 read-modify-write accumulator
    passes per offset plus a dequant materialization on top of the 6
    elementwise passes the fused kernel replaces.

    Exchange-volume accounting uses ACTIVE edges: with a dynamic topology
    (``dyn_topology``: a ``repro.topology.TopologyConfig``), a fully-gated
    offset round skips its permute, so expected wire volume counts the
    scheduler's expected ACTIVE OFFSETS (per-offset all-or-nothing — a
    partially gated offset still permutes the whole buffer; dead spare
    offsets cost nothing). The HBM model still streams the compiled offset
    superset — wire buffers are stacked regardless. ``active_edge_frac``
    reports the finer edge-level fraction (zero-math gated edges).

    ``shard_consensus`` switches every per-device figure to the SHARDED
    engine: the flat state and the kernel's HBM passes shrink by the
    in-pod axis size (each device streams only its slab), each permute
    moves one per-shard wire slab per device, and the report adds a
    per-device ``consensus_state`` breakdown for both modes (the ISSUE
    acceptance shrink).
    """
    from repro import wire
    from repro.core.graph import build_graph
    from repro.distributed.sharding import inpod_axes
    from repro.optim import flatten
    from repro.topology import TopologyConfig, TopologyRuntime

    import jax.numpy as jnp

    # same guards as ConsensusTrainer (via the shared inpod_axes helper):
    # a single-pod mesh runs no consensus round, so nothing shards
    _, inner_size = inpod_axes(mesh)
    n_shards = inner_size if (shard_consensus and inner_size > 1
                              and int(mesh.shape["pod"]) > 1) else 1
    ap = model.abstract_params()
    bs = block_size or flatten.auto_block_size(ap)
    lay = flatten.FlatLayout.for_tree(ap, block_size=bs, node_axis=False,
                                      shards=n_shards)
    # wire volume is read from the codec — the same object the trainer
    # encodes with and the ledger sizes rows from, so the roofline cannot
    # drift from the bytes a permute actually moves
    codec = wire.get_codec(compression, lay,
                           lay.shard(n_shards) if n_shards > 1 else None)
    j = int(mesh.shape["pod"])
    topo_rt = TopologyRuntime(build_graph(topology, j),
                              dyn_topology or TopologyConfig())
    deg = len(topo_rt.offsets) or 1            # compiled offset superset
    active_frac = topo_rt.expected_active_fraction()
    # wire is per-OFFSET all-or-nothing: a permute is skipped only when its
    # whole offset round is dead (dead spare offsets cost no wire)
    active_offsets = topo_rt.expected_active_offsets() or 1.0
    n = lay.total
    tb = jnp.dtype(lay.wire_dtype).itemsize            # theta element bytes
    # per NODE per round (sum over the node's shards: each shard's message
    # carries its own scale bytes)
    row_bytes = codec.wire_bytes()
    wire_bytes = int(active_offsets * row_bytes)
    # kernel, per NODE: read theta (tb) + lam/bar_prev (f32) + deg wires,
    # write theta (tb) + lam/bar (f32). The *_per_device variants divide
    # by the shard grid (each device streams only its slab); the naive
    # per-leaf path is replicated in-pod, so its per-node and per-device
    # figures coincide — compare the *_passes fields (same per-node base)
    # for the fusion win alone, and naive_s / fused_kernel_s for wall
    # clock (which legitimately includes the parallel-slab-streaming win).
    fused_hbm = n * (2 * tb + 4 * 4) + deg * row_bytes
    fused_hbm_dev = fused_hbm // n_shards
    # naive per-leaf path adds ~2 accumulator read-modify-write passes per
    # offset plus a full dequant materialization (all f32, unsharded)
    naive_hbm = n * (2 * tb + 4 * 4) + deg * lay.wire_bytes(compression) \
        + deg * n * 4 * 3
    # observability overhead (repro.obs, when enabled): the ring append is
    # one [n_metrics] f32 row of HBM write per round; a drain pulls the
    # whole [cap, n_metrics] buffer device->host once every K rounds.
    # Both are constants — invisible next to the flat-buffer passes (the
    # <= 3% measured gate lives in BENCH_obs.json).
    obs_acct = {}
    if obs_ring_cap > 0:
        from repro.obs import schema as obs_schema
        c_cols = obs_schema.NUM_COLUMNS
        n_cols = obs_schema.NUM_NODE_COLUMNS
        obs_acct = {"obs": {
            "ring_hbm_bytes": 4 * obs_ring_cap * c_cols,
            "ring_write_bytes_per_round": 4 * c_cols,
            "drain_bytes_per_round":
                4 * obs_ring_cap * c_cols // max(obs_drain_every, 1),
            # per-node telemetry ring ([cap, J, n_node_cols]): one [J,
            # n_node_cols] slab written per round, whole buffer per drain
            "node_ring_hbm_bytes": 4 * obs_ring_cap * j * n_cols,
            "node_ring_write_bytes_per_round": 4 * j * n_cols,
            "node_ring_drain_bytes_per_round":
                4 * obs_ring_cap * j * n_cols // max(obs_drain_every, 1),
            "drain_every": obs_drain_every,
        }}
    return {
        "wire_codec": codec.name,
        "flat_elems": n, "block_size": bs, "blocks": lay.num_blocks,
        "padding_frac": round(lay.waste_frac, 4),
        "offsets_compiled": deg,
        "active_edge_frac": round(active_frac, 4),
        "active_offsets": round(active_offsets, 2),
        "n_shards": n_shards,
        "wire_bytes_per_round": wire_bytes,
        "wire_bytes_per_device": int(active_offsets * row_bytes
                                     / n_shards),
        "fused_hbm_bytes": fused_hbm,
        "fused_hbm_bytes_per_device": fused_hbm_dev,
        "fused_hbm_passes": round(fused_hbm / (n * 4), 2),
        "naive_hbm_bytes": naive_hbm,
        "naive_hbm_passes": round(naive_hbm / (n * 4), 2),
        "fused_kernel_s": fused_hbm_dev / HBM_BW,
        "naive_s": naive_hbm / HBM_BW,
        "consensus_state": {
            "per_device": consensus_state_bytes(
                lay, deg=deg, compression=compression, n_shards=n_shards,
                with_ledger=with_ledger, obs_ring_cap=obs_ring_cap,
                obs_num_nodes=j),
            "per_device_unsharded": consensus_state_bytes(
                lay, deg=deg, compression=compression, n_shards=1,
                with_ledger=with_ledger, obs_ring_cap=obs_ring_cap,
                obs_num_nodes=j),
        },
        **obs_acct,
    }


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int) -> dict:
    """Three-term roofline (seconds). cost_analysis is per-device already."""
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = hbm_bytes / HBM_BW
    # v5e: 4 ICI links per chip usable; bytes here are per-device program
    coll_s = coll_bytes / (ICI_BW_PER_LINK)
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", coll_s), key=lambda kv: kv[1])[0]
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": dominant}


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca) if ca else {}


def _mem_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        out[attr] = int(getattr(ma, attr, 0) or 0)
    out["total_hbm_bytes"] = (out["argument_size_in_bytes"]
                              + out["output_size_in_bytes"]
                              + out["temp_size_in_bytes"]
                              - out.get("alias_size_in_bytes", 0))
    return out


def model_flops(model: Model, cell: ShapeCell) -> float:
    """6 N D (dense) / 6 N_active D — the useful-FLOPs yardstick."""
    n = model.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch          # decode: one token per seq


# §Perf knobs consumed here (benchmarks/perf_iter.py sets them per variant)
KNOBS = {
    "grad_rs": False,        # reduce-scatter grads to param shards
    "compression": "none",   # legacy spelling of the wire codec
    "wire_codec": "",        # repro.wire codec; "" resolves from compression
    "probe_frac": 1,         # probe-batch reduction for the consensus round
    "topo_scheduler": "static",  # dynamic-topology edge scheduler
    "shard_consensus": False,    # in-pod sharded flat consensus state
    "pipeline_offsets": 1,       # round-pipeline depth (1 = sequential)
    "obs_ring_cap": 0,           # obs metrics-ring rows; 0 = obs off
    "obs_drain_every": 8,        # obs host-drain cadence (rounds)
}


def _knob_codec() -> str:
    """The wire-codec spec the KNOBS currently select."""
    return KNOBS["wire_codec"] or KNOBS["compression"]


def _knob_obs_config():
    """The ObsConfig the KNOBS select (None = obs compiled out)."""
    if KNOBS["obs_ring_cap"] <= 0:
        return None
    from repro.obs import ObsConfig
    return ObsConfig(ring_capacity=KNOBS["obs_ring_cap"],
                     drain_every=KNOBS["obs_drain_every"])


def _compile_step(cfg: ArchConfig, cell: ShapeCell, mesh, *,
                  consensus: bool, which: str = "main"):
    """Lower+compile the step function for a config variant.

    which: 'main' (train/prefill/decode per cell.kind) or 'consensus'.
    """
    model = build_model(cfg)
    rules = arch_rules(cfg, mesh)
    in_specs = input_specs(cfg, cell)
    in_shard = input_spec_shardings(cfg, cell, mesh)

    if cell.kind == "train":
        acfg = AdamWConfig(
            factored=cfg.moe is not None,
            moment_dtype=jnp.bfloat16 if cfg.moe is not None
            else jnp.float32)
        if consensus:
            from repro.topology import TopologyConfig
            trainer = ConsensusTrainer(
                model, mesh, adamw=acfg,
                consensus=ConsensusConfig(
                    penalty=PenaltyConfig(scheme="nap", eta0=0.1),
                    topology="ring", local_steps=8,
                    compression=KNOBS["compression"],
                    wire_codec=KNOBS["wire_codec"],
                    grad_rs=KNOBS["grad_rs"],
                    shard_consensus=KNOBS["shard_consensus"],
                    pipeline_offsets=KNOBS["pipeline_offsets"],
                    dyn_topology=TopologyConfig(
                        scheduler=KNOBS["topo_scheduler"]),
                    obs=(_knob_obs_config())))
            state = trainer.abstract_state()
            state_sh = trainer.state_shardings()
            j = trainer.num_nodes
            batch = {k: jax.ShapeDtypeStruct(
                (j, v.shape[0] // j) + v.shape[1:], v.dtype)
                for k, v in in_specs.items()}
            batch_sh = {k: NamedSharding(mesh, P("pod", "data", *([None] * (
                len(batch[k].shape) - 2)))) for k in batch}
            if which == "consensus" and KNOBS["probe_frac"] > 1:
                pf = KNOBS["probe_frac"]
                batch = {k: jax.ShapeDtypeStruct(
                    (v.shape[0], max(1, v.shape[1] // pf)) + v.shape[2:],
                    v.dtype) for k, v in batch.items()}
            fn = trainer.consensus_step if which == "consensus" \
                else trainer.train_step
            step = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                           out_shardings=(state_sh, None))
            return step.lower(state, batch).compile()
        _, step_fn, abstract_state, state_shardings = make_train_fns(
            model, mesh, acfg, grad_rs=KNOBS["grad_rs"])
        state = abstract_state()
        state_sh = state_shardings()
        step = jax.jit(step_fn, in_shardings=(state_sh, in_shard),
                       out_shardings=(state_sh, None))
        return step.lower(state, in_specs).compile()

    with shd.use_mesh(mesh, rules):
        pspec = model.param_specs()
    params = model.abstract_params()
    params_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspec,
        is_leaf=lambda s: isinstance(s, P))
    if cell.kind == "prefill":
        prefill_fn, _ = make_serve_fns(model, mesh, cell)
        fn = jax.jit(prefill_fn, in_shardings=(params_sh, in_shard))
        return fn.lower(params, in_specs).compile()
    _, decode_fn = make_serve_fns(model, mesh, cell)
    dstate, dstate_sh = decode_state_specs(cfg, mesh, cell.global_batch,
                                           cell.seq_len)
    fn = jax.jit(decode_fn,
                 in_shardings=(params_sh, dstate_sh, in_shard),
                 out_shardings=(None, dstate_sh))
    return fn.lower(params, dstate, in_specs).compile()


_NUMERIC_KEYS = ("flops_per_device", "hbm_bytes")


def _corrected_record(cfg: ArchConfig, cell: ShapeCell, mesh, *,
                      consensus: bool, which: str = "main") -> dict:
    """Trip-count-corrected cost record.

    ``cost_analysis``/HLO text count a while-loop body ONCE regardless of
    trip count, so the layer scan (and any per-timestep scan) hides FLOPs.
    We difference auxiliary 1- and 2-layer lowers (and time-unroll 1 vs 2 for
    SSM/RWKV time scans) to recover per-layer / per-step costs, then
    extrapolate:  total = f_main + (L-1)*layer + L*(T-1)*time.
    Memory analysis comes from the real (scan) artifact — buffer reuse in the
    loop is genuine, so no extrapolation there.
    """
    import dataclasses as dc
    from repro.models import transformer as tfm
    from repro.models import rwkv6 as rwkvm
    from repro.models import ssm as ssmm

    main = _record(_compile_step(cfg, cell, mesh, consensus=consensus,
                                 which=which))
    has_time_scan = (cfg.rwkv or cfg.ssm_state > 0) and cell.kind != "decode"

    cfg1 = dc.replace(cfg, n_layers=1)
    cfg2 = dc.replace(cfg, n_layers=2)
    tfm.SCAN_UNROLL = 2          # fully unroll the 2-layer stack
    try:
        f1 = _record(_compile_step(cfg1, cell, mesh, consensus=consensus,
                                   which=which))
        f2 = _record(_compile_step(cfg2, cell, mesh, consensus=consensus,
                                   which=which))
        if has_time_scan:
            rwkvm.TIME_UNROLL = 2
            ssmm.TIME_UNROLL = 2
            ft = _record(_compile_step(cfg1, cell, mesh,
                                       consensus=consensus, which=which))
            rwkvm.TIME_UNROLL = 1
            ssmm.TIME_UNROLL = 1
        else:
            ft = None
    finally:
        tfm.SCAN_UNROLL = 1
        rwkvm.TIME_UNROLL = 1
        ssmm.TIME_UNROLL = 1

    l = cfg.n_layers
    t_steps = cell.seq_len if has_time_scan else 1
    if has_time_scan and cfg.rwkv and rwkvm.TIME_CHUNK > 0:
        t_steps = max(1, cell.seq_len // rwkvm.TIME_CHUNK)
    out = dict(main)
    corrected = {}
    for key in _NUMERIC_KEYS + ("wire_total",):
        get = (lambda r, k=key: r["collectives"]["wire_total"]
               if k == "wire_total" else r[k])
        time_body = max(get(ft) - get(f1), 0.0) if ft is not None else 0.0
        if key == "wire_total":
            # verified via HLO inspection: the per-timestep scan bodies are
            # collective-free (identical collective sets at chunk=0 vs 64),
            # so any unroll-diff delta is layout noise — extrapolate
            # collectives over LAYERS only.
            time_body = 0.0
        layer_body = max(get(f2) - get(f1) - time_body, 0.0)
        # main counts one layer body once (incl. one time body)
        total = get(main) + (l - 1) * (layer_body + time_body) \
            + l * (t_steps - 1) * time_body
        corrected[key] = total
    out["flops_per_device"] = corrected["flops_per_device"]
    out["hbm_bytes"] = corrected["hbm_bytes"]
    out["collectives"] = dict(main["collectives"])
    out["collectives"]["wire_total"] = corrected["wire_total"]
    out["uncorrected"] = {k: main[k] for k in _NUMERIC_KEYS}
    out["uncorrected"]["wire_total"] = main["collectives"]["wire_total"]
    return out


def lower_cell(cfg: ArchConfig, cell: ShapeCell, *, multi_pod: bool,
               consensus: bool = True) -> dict:
    """Lower + compile one (arch x shape x mesh) cell; return the record."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    model = build_model(cfg)
    rec: dict = {"arch": cfg.arch_id, "shape": cell.name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "chips": chips, "kind": cell.kind,
                 "params_b": model.param_count() / 1e9,
                 "active_params_b": model.active_param_count() / 1e9}
    t0 = time.time()
    use_consensus = consensus and multi_pod and cell.kind == "train"
    key = {"train": "train", "prefill": "prefill", "decode": "decode"}[
        cell.kind]
    rec[key] = _corrected_record(cfg, cell, mesh, consensus=use_consensus)
    if use_consensus:
        rec["consensus"] = _corrected_record(cfg, cell, mesh,
                                             consensus=True,
                                             which="consensus")
        from repro.topology import TopologyConfig as _TC
        rec["consensus"]["fused_round_model"] = fused_round_roofline(
            model, mesh, compression=_knob_codec(),
            dyn_topology=_TC(scheduler=KNOBS["topo_scheduler"]),
            shard_consensus=KNOBS["shard_consensus"],
            obs_ring_cap=KNOBS["obs_ring_cap"],
            obs_drain_every=KNOBS["obs_drain_every"])
    rec["lower_compile_s"] = round(time.time() - t0, 1)
    main = rec[key]
    mf = model_flops(model, cell)
    rec["model_flops"] = mf
    hlo_flops_global = main["flops_per_device"] * chips
    rec["useful_flop_frac"] = (mf / hlo_flops_global
                               if hlo_flops_global else 0.0)
    rec["roofline"] = roofline_terms(main["flops_per_device"],
                                     main["hbm_bytes"],
                                     main["collectives"]["wire_total"],
                                     chips)
    return rec


def _record(compiled) -> dict:
    cost = _cost_dict(compiled)
    mem = _mem_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return {
        "flops_per_device": float(cost.get("flops", 0.0)),
        "hbm_bytes": float(cost.get("bytes accessed", 0.0)),
        "memory": mem,
        "bytes_per_device_gb": mem["total_hbm_bytes"] / 2**30,
        "collectives": coll,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--no-consensus", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply the §Perf-confirmed optimization package "
                         "(head padding, bf16 probs, serialized chunks, "
                         "int8 consensus wire, fractional probes)")
    args = ap.parse_args(argv)
    if args.opt:
        from repro.models import attention as _at
        _at.PAD_HEADS_MULT = 16
        _at.PROBS_BF16 = True
        _at.SERIAL_CHUNKS = True
        KNOBS["compression"] = "int8"
        KNOBS["probe_frac"] = 8

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if "error" not in r}

    for cfg, cell, skip in cells():
        if args.arch != "all" and cfg.arch_id != args.arch:
            continue
        if args.shape != "all" and cell.name != args.shape:
            continue
        meshes = {"single": [False], "multi": [True],
                  "both": [False, True]}[args.mesh]
        for multi_pod in meshes:
            mesh_name = "2x16x16" if multi_pod else "16x16"
            key = (cfg.arch_id, cell.name, mesh_name)
            if key in done:
                continue
            if skip:
                results.append({
                    "arch": cfg.arch_id, "shape": cell.name,
                    "mesh": mesh_name, "skipped": True,
                    "reason": "full quadratic attention at 512k seq "
                              "(no sub-quadratic path); see DESIGN.md §4"})
                _flush(args.out, results)
                continue
            print(f"=== {cfg.arch_id} x {cell.name} x {mesh_name}",
                  flush=True)
            try:
                rec = lower_cell(cfg, cell, multi_pod=multi_pod,
                                 consensus=not args.no_consensus)
                print(f"    ok in {rec['lower_compile_s']}s  "
                      f"dom={rec['roofline']['dominant']}  "
                      f"bytes/dev={rec.get('train', rec.get('prefill', rec.get('decode')))['bytes_per_device_gb']:.2f}GB",
                      flush=True)
                results.append(rec)
            except Exception as e:  # noqa: BLE001 — record and continue
                traceback.print_exc()
                results.append({"arch": cfg.arch_id, "shape": cell.name,
                                "mesh": mesh_name, "error": str(e)[:2000]})
            _flush(args.out, results)
    n_err = sum(1 for r in results if "error" in r)
    print(f"done: {len(results)} records, {n_err} errors")
    return 1 if n_err else 0


def _flush(path, results):
    with open(path + ".tmp", "w") as f:
        json.dump(results, f, indent=1)
    os.replace(path + ".tmp", path)


if __name__ == "__main__":
    sys.exit(main())
