"""Production mesh construction + backend (XLA) flag setup.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import os
import warnings

import jax

# XLA knobs for the latency-hiding round pipeline: let the scheduler pull
# each graph offset's collective-permute-start above the previous offset's
# decode/probe compute (the trainer issues them up front behind
# optimization_barriers — see docs/consensus_engine.md "Round pipeline").
# Async collective conversion itself is default-on in this XLA vintage
# (the old --xla_gpu_enable_async_collectives flag no longer exists), so
# the tunables that matter are the scheduler + stream priority + pipelined
# collectives. All three parse on every backend (the registry is global);
# CPU simply ignores the gpu-prefixed knobs.
ASYNC_COLLECTIVE_FLAGS = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
    "--xla_gpu_enable_pipelined_collectives=true",
)


def backend_initialized() -> bool:
    """True once any jax backend client exists (XLA_FLAGS are locked in)."""
    try:
        from jax._src import xla_bridge
        return bool(xla_bridge._backends)
    except Exception:                       # pragma: no cover - jax internals
        # conservative fallback: assume initialized so we never silently
        # set flags that can no longer take effect
        return True


def set_backend_flags(*, async_collectives: bool = True,
                      host_device_count: int | None = None,
                      extra: tuple[str, ...] = ()) -> str | None:
    """Arm XLA_FLAGS for the round pipeline BEFORE first jax touch.

    Appends to — never clobbers — a user-set ``XLA_FLAGS`` env var, and
    skips any flag the user already spelled (their value wins). After jax
    backend initialization the env var is parsed and locked, so this
    becomes a warn-and-return no-op instead of silently writing flags
    that do nothing. Returns the new ``XLA_FLAGS`` value, or None when
    nothing changed.

    ``host_device_count`` adds ``--xla_force_host_platform_device_count``
    (the dry-run's 512-fake-device knob — it depends on this running
    before any backend init, hence the ordering guard).
    """
    wanted = list(ASYNC_COLLECTIVE_FLAGS) if async_collectives else []
    if host_device_count is not None:
        wanted.append("--xla_force_host_platform_device_count="
                      f"{int(host_device_count)}")
    wanted.extend(extra)
    if not wanted:
        return None
    if backend_initialized():
        warnings.warn(
            "set_backend_flags() called after jax initialized a backend: "
            "XLA_FLAGS are already locked in — flags not applied. Call it "
            "before the first jax device/computation touch.",
            RuntimeWarning, stacklevel=2)
        return None
    current = os.environ.get("XLA_FLAGS", "")
    present = {f.split("=", 1)[0] for f in current.split() if f}
    add = [f for f in wanted if f.split("=", 1)[0] not in present]
    if not add:
        return current or None
    merged = (current + " " if current else "") + " ".join(add)
    os.environ["XLA_FLAGS"] = merged
    return merged


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist in newer jax;
    on 0.4.x every axis is Auto by default, which is exactly what we want, so
    the kwarg is simply omitted when the enum is missing.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips) v5e mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Small mesh for CPU integration tests (8 fake devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


# v5e hardware constants for the roofline model (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW_PER_LINK = 50e9            # bytes/s per link
