"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist in newer jax;
    on 0.4.x every axis is Auto by default, which is exactly what we want, so
    the kwarg is simply omitted when the enum is missing.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips) v5e mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Small mesh for CPU integration tests (8 fake devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


# v5e hardware constants for the roofline model (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW_PER_LINK = 50e9            # bytes/s per link
