"""Distributed PPCA (Yoon & Pavlovic, NIPS'12) with the paper's adaptive
penalty schedules — the faithful reproduction of §4 / Algorithm 1.

Every node i holds local observations X_i [N_i, D] and local parameters
Theta_i = {W_i, mu_i, a_i}; consensus constraints tie the parameters across
the communication graph. One ADMM iteration (Algorithm 1):

  1. E-step (local, same as centralized PPCA)
  2. M-step with consensus terms (eq. 15 and its W/a analogues)
  3. broadcast Theta_i to neighbors
  4. dual updates  Lam_i += 1/2 sum_j eta_ij (W_i - W_j)  (and gamma, beta)
  5. penalty update eta_ij / budget T_ij via the configured scheme (eq. 4–12)

Single-host reproduction layout: all node states stacked on a leading J axis
and the per-node math vmapped; neighbor reductions are masked matmuls with
the dense adjacency. This mirrors exactly what the sharded trainer does on a
mesh, with the node axis mapped onto devices instead.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import residuals as res_lib
from repro.core.graph import Graph
from repro.core.penalty import (PenaltyConfig, PenaltyState,
                                init_penalty_state, update_penalty)
from repro.ppca import ppca as cp


class DPPCAState(NamedTuple):
    W: jax.Array          # [J, D, M]
    mu: jax.Array         # [J, D]
    a: jax.Array          # [J]
    Lam: jax.Array        # [J, D, M]  multiplier for W
    gam: jax.Array        # [J, D]     multiplier for mu
    bet: jax.Array        # [J]        multiplier for a
    theta_bar: dict       # previous neighbor means (for eq. 5 dual residual)
    penalty: PenaltyState
    t: jax.Array


@dataclasses.dataclass(frozen=True, eq=False)
class DPPCA:
    """D-PPCA with configurable penalty schedule."""

    latent_dim: int
    graph: Graph
    penalty_cfg: PenaltyConfig
    probe_midpoint: bool = False   # §3.2: probe at rho_ij instead of theta_j

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array, x: jax.Array) -> DPPCAState:
        """x: [J, N_i, D] local observations (evenly split)."""
        j, _, d = x.shape
        m = self.latent_dim
        keys = jax.random.split(key, j)
        W = jax.vmap(lambda k: jax.random.normal(k, (d, m)))(keys)
        mu = x.mean(axis=1)
        a = jnp.ones((j,), x.dtype)
        theta = {"W": W, "mu": mu, "a": a}
        bar = res_lib.neighbor_mean(theta, jnp.asarray(self.graph.adj))
        return DPPCAState(
            W=W.astype(x.dtype), mu=mu, a=a,
            Lam=jnp.zeros_like(W), gam=jnp.zeros_like(mu),
            bet=jnp.zeros_like(a), theta_bar=bar,
            penalty=init_penalty_state(self.penalty_cfg, j, x.dtype),
            t=jnp.zeros((), jnp.int32))

    # ------------------------------------------------------------- iteration
    @partial(jax.jit, static_argnums=0)
    def step(self, state: DPPCAState, x: jax.Array
             ) -> tuple[DPPCAState, dict]:
        j, n_i, d = x.shape
        m = self.latent_dim
        adj = jnp.asarray(self.graph.adj)
        adj_f = adj.astype(x.dtype)
        eta = state.penalty.eta * adj_f              # zero off-edges
        eta_sum = eta.sum(axis=1)                    # [J] sum_j eta_ij

        # ---- (1) E-step, vmapped over nodes --------------------------------
        params = jax.vmap(cp.PPCAParams)(state.W, state.mu, state.a)
        stats = jax.vmap(cp.e_step)(params, x)

        # ---- (2) M-step with consensus -------------------------------------
        # W update:  [a_i sum_n xc Ez^T - 2 Lam_i + sum_j eta_ij (W_i + W_j)]
        #            [a_i sum_n Ezz + 2 sum_j eta_ij I]^{-1}
        nbr_W = jnp.einsum("ij,jdm->idm", eta, state.W)       # sum_j eta W_j
        own_W = eta_sum[:, None, None] * state.W              # sum_j eta W_i

        def w_update(x_i, mu_i, a_i, Ez, Ezz, Lam_i, pull, es):
            xc = x_i - mu_i[None]
            num = a_i * (xc.T @ Ez) - 2.0 * Lam_i + pull       # [D, M]
            den = a_i * Ezz.sum(0) + 2.0 * es * jnp.eye(m, dtype=x_i.dtype)
            return jnp.linalg.solve(den, num.T).T

        W_new = jax.vmap(w_update)(x, state.mu, state.a, stats.Ez, stats.Ezz,
                                   state.Lam, nbr_W + own_W, eta_sum)

        # mu update (paper eq. 15)
        nbr_mu = eta @ state.mu                               # [J, D]
        own_mu = eta_sum[:, None] * state.mu

        def mu_update(x_i, W_i, a_i, Ez, gam_i, pull, es):
            num = a_i * jnp.sum(x_i - Ez @ W_i.T, axis=0) - 2.0 * gam_i + pull
            return num / (n_i * a_i + 2.0 * es)

        mu_new = jax.vmap(mu_update)(x, W_new, state.a, stats.Ez, state.gam,
                                     nbr_mu + own_mu, eta_sum)

        # a update: positive root of
        #   4*es*a^2 + (s_i + 4 bet_i - 2 sum_j eta_ij(a_i + a_j)) a - N D = 0
        nbr_a = eta @ state.a + eta_sum * state.a             # [J]

        def a_update(x_i, W_i, mu_i, Ez, Ezz, bet_i, pull, es):
            xc = x_i - mu_i[None]
            s = (jnp.sum(xc * xc) - 2.0 * jnp.sum((xc @ W_i) * Ez)
                 + jnp.sum(Ezz * (W_i.T @ W_i)[None]))
            b = s + 4.0 * bet_i - 2.0 * pull
            c2 = 4.0 * es
            nd = jnp.asarray(n_i * d, x_i.dtype)
            root = (-b + jnp.sqrt(b * b + 4.0 * c2 * nd)) / (2.0 * c2 + 1e-30)
            no_consensus = nd / jnp.maximum(b, 1e-12)  # es == 0 fallback
            a = jnp.where(c2 > 1e-12, root, no_consensus)
            return jnp.maximum(a, 1e-8)

        a_new = jax.vmap(a_update)(x, W_new, mu_new, stats.Ez, stats.Ezz,
                                   state.bet, nbr_a, eta_sum)

        # ---- (3)+(4) broadcast & dual updates -------------------------------
        # Dual updates use the SYMMETRIZED per-edge penalty. With directed
        # eta_ij != eta_ji the raw update breaks the sum_i lambda_i = 0
        # invariant, tilting (and for the precision, unbounding) the fixed
        # point. Symmetric duals + directed primal pulls keep the paper's
        # directed-edge adaptivity while preserving the invariant that its
        # convergence argument (Remark 4.2 of [10]) relies on. DESIGN.md §7.
        eta_sym = 0.5 * (eta + eta.T)

        def dual(mult, th):
            flat = th.reshape(j, -1)
            diff = eta_sym.sum(1)[:, None] * flat - eta_sym @ flat
            return mult + 0.5 * diff.reshape(th.shape)

        Lam_new = dual(state.Lam, W_new)
        gam_new = dual(state.gam, mu_new)
        bet_new = dual(state.bet[:, None], a_new[:, None])[:, 0]

        # ---- residuals (eq. 5) over the full parameter pytree ---------------
        theta = {"W": W_new, "mu": mu_new, "a": a_new}
        eta_node = res_lib.node_eta(state.penalty.eta, adj)
        rr = res_lib.local_residuals(theta, state.theta_bar, adj, eta_node)

        # ---- (5) penalty update ---------------------------------------------
        params_new = jax.vmap(cp.PPCAParams)(W_new, mu_new, a_new)
        f_self = jax.vmap(cp.nll)(params_new, x)

        f_nbr = None
        if self.penalty_cfg.uses_objective_probes:
            def probe_row(x_i, W_i, mu_i, a_i):
                def at(W_j, mu_j, a_j):
                    if self.probe_midpoint:
                        W_j = 0.5 * (W_i + W_j)
                        mu_j = 0.5 * (mu_i + mu_j)
                        a_j = 0.5 * (a_i + a_j)
                    return cp.nll(cp.PPCAParams(W_j, mu_j, a_j), x_i)
                return jax.vmap(at)(W_new, mu_new, a_new)

            f_nbr = jax.vmap(probe_row)(x, W_new, mu_new, a_new)

        penalty_new = update_penalty(
            self.penalty_cfg, state.penalty, adj=adj, f_self=f_self,
            f_nbr=f_nbr, r_norm=rr.r_norm, s_norm=rr.s_norm)

        new_state = DPPCAState(
            W=W_new, mu=mu_new, a=a_new, Lam=Lam_new, gam=gam_new,
            bet=bet_new, theta_bar=rr.theta_bar, penalty=penalty_new,
            t=state.t + 1)
        metrics = {
            "objective": f_self.sum(),
            "f_self": f_self,
            "r_max": rr.r_norm.max(),
            "s_max": rr.s_norm.max(),
            "eta_mean": res_lib.node_eta(penalty_new.eta, adj).mean(),
        }
        return new_state, metrics

    # ------------------------------------------------------------------- run
    def run(self, state: DPPCAState, x: jax.Array, *, max_iters: int = 1000,
            rel_tol: float = 1e-3, min_iters: int = 5
            ) -> tuple[DPPCAState, dict]:
        """Paper §5 criterion: relative change of the total objective < tol."""
        hist = {"objective": [], "r_max": [], "eta_mean": []}
        prev = None
        iters = max_iters
        for it in range(max_iters):
            state, mtr = self.step(state, x)
            obj = float(mtr["objective"])
            hist["objective"].append(obj)
            hist["r_max"].append(float(mtr["r_max"]))
            hist["eta_mean"].append(float(mtr["eta_mean"]))
            if prev is not None and it + 1 >= min_iters:
                if abs(obj - prev) / (abs(prev) + 1e-12) < rel_tol:
                    iters = it + 1
                    break
            prev = obj
        hist["iterations"] = iters
        return state, hist


def max_subspace_angle(W_nodes: jax.Array, W_ref: jax.Array) -> jax.Array:
    """Paper metric: max over nodes of the largest principal angle (degrees)."""
    angles = jax.vmap(lambda w: cp.subspace_angle(w, W_ref))(W_nodes)
    return jnp.rad2deg(angles.max())
