"""Synthetic data generators for the reproduction experiments.

``subspace_data``  — §5.1: 500 samples, 20-dim observations from a 5-dim
subspace with N(0, I) latents and N(0, 0.2 I) measurement noise, split
evenly across J nodes.

``turntable_sfm``  — §5.2-style distributed affine structure-from-motion:
a rigid 3D point cloud observed by an orthographic turntable camera over F
frames; frames are split evenly across J camera nodes (Fig. 4: 30 frames,
5 cameras). The Caltech/Hopkins images are not available offline, so we
generate matched-dimension synthetic tracks; the claims under test are
relative-convergence claims, which survive the swap (DESIGN.md §7).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class SubspaceData(NamedTuple):
    x: np.ndarray        # [J, N_i, D]  per-node observations
    W_true: np.ndarray   # [D, M]       generating subspace
    x_all: np.ndarray    # [N, D]       pooled (for the centralized baseline)


def subspace_data(num_nodes: int, *, n: int = 500, d: int = 20, m: int = 5,
                  noise_std: float = np.sqrt(0.2), seed: int = 0
                  ) -> SubspaceData:
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(d, m))
    z = rng.normal(size=(n, m))
    x = z @ W.T + noise_std * rng.normal(size=(n, d))
    n_i = n // num_nodes
    x_nodes = x[: n_i * num_nodes].reshape(num_nodes, n_i, d)
    return SubspaceData(x=x_nodes.astype(np.float64),
                        W_true=W.astype(np.float64),
                        x_all=x.astype(np.float64))


class SfMData(NamedTuple):
    measurements: np.ndarray  # [2F, N] stacked affine image measurements
    x_nodes: np.ndarray       # [J, 2F_i, N] per-camera rows (transposed PPCA
                              #   layout: samples = frame-rows, dim = points)
    structure: np.ndarray     # [N, 3] ground-truth 3D points
    motion: np.ndarray        # [2F, 3] ground-truth affine motion


def turntable_sfm(num_cameras: int = 5, *, frames: int = 30, points: int = 90,
                  noise_std: float = 0.01, seed: int = 0) -> SfMData:
    """Orthographic turntable: object rotates about the vertical axis.

    Per Yoon & Pavlovic's SfM setup we run PPCA on the *transposed*
    measurement matrix: each camera's samples are its own 2*F_i frame-rows
    (dimension = N points), so the consensus parameter W in R^{N x 3} *is*
    the reconstructed 3D structure — matching the paper's metric, the
    subspace angle of the reconstructed structure vs. centralized SVD.
    """
    rng = np.random.default_rng(seed)
    # rigid object: random cloud in a unit box, non-degenerate
    s3d = rng.uniform(-1.0, 1.0, size=(points, 3))
    angles = np.linspace(0.0, 2.0 * np.pi * (frames - 1) / frames, frames)
    rows = []
    for ang in angles:
        c, s = np.cos(ang), np.sin(ang)
        rot = np.array([[c, 0.0, s], [0.0, 1.0, 0.0], [-s, 0.0, c]])
        proj = rot[:2]                      # orthographic: keep x, y rows
        rows.append(proj)
    motion = np.concatenate(rows, axis=0)                     # [2F, 3]
    meas = motion @ s3d.T                                     # [2F, N]
    meas = meas + noise_std * rng.normal(size=meas.shape)
    f_i = frames // num_cameras
    x_nodes = np.stack([meas[2 * f_i * i: 2 * f_i * (i + 1)]
                        for i in range(num_cameras)])         # [J, 2F_i, N]
    return SfMData(measurements=meas.astype(np.float64),
                   x_nodes=x_nodes.astype(np.float64),
                   structure=s3d.astype(np.float64),
                   motion=motion.astype(np.float64))
