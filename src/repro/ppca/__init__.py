"""Paper's application: (distributed) probabilistic PCA for SfM."""
from repro.ppca.dppca import DPPCA, DPPCAState, max_subspace_angle
from repro.ppca.ppca import (EStats, PPCAParams, e_step, fit_em, fit_svd,
                             init_params, m_step, nll, subspace_angle)
from repro.ppca.synth import SfMData, SubspaceData, subspace_data, turntable_sfm

__all__ = [
    "DPPCA", "DPPCAState", "max_subspace_angle",
    "EStats", "PPCAParams", "e_step", "fit_em", "fit_svd", "init_params",
    "m_step", "nll", "subspace_angle",
    "SfMData", "SubspaceData", "subspace_data", "turntable_sfm",
]
