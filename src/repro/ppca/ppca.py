"""Centralized Probabilistic PCA (Tipping & Bishop, 1999) — EM + closed form.

The model:  x = W z + mu + eps,   z ~ N(0, I_M),  eps ~ N(0, a^{-1} I_D)
with noise *precision* a (the paper's convention, §4.1).

Used as (a) the local solver inside D-PPCA's M-step structure, (b) the
centralized baseline/ground-truth generator for the reproduction experiments,
and (c) the oracle for unit tests.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class PPCAParams(NamedTuple):
    W: jax.Array    # [D, M] projection
    mu: jax.Array   # [D]    mean
    a: jax.Array    # []     noise precision (1/sigma^2)


class EStats(NamedTuple):
    Ez: jax.Array     # [N, M]     posterior means  E[z_n]
    Ezz: jax.Array    # [N, M, M]  posterior second moments E[z_n z_n^T]


def init_params(key: jax.Array, d: int, m: int,
                dtype=jnp.float32) -> PPCAParams:
    kw, _ = jax.random.split(key)
    return PPCAParams(W=jax.random.normal(kw, (d, m), dtype),
                      mu=jnp.zeros((d,), dtype),
                      a=jnp.asarray(1.0, dtype))


def e_step(params: PPCAParams, x: jax.Array) -> EStats:
    """Posterior stats (paper eq. 13): M = W^T W + a^{-1} I."""
    W, mu, a = params
    m = W.shape[1]
    Mmat = W.T @ W + jnp.eye(m, dtype=W.dtype) / a
    Minv = jnp.linalg.inv(Mmat)
    xc = x - mu[None, :]
    Ez = xc @ W @ Minv.T                              # [N, M]
    Ezz = Minv / a + Ez[:, :, None] * Ez[:, None, :]  # [N, M, M]
    return EStats(Ez=Ez, Ezz=Ezz)


def m_step(stats: EStats, x: jax.Array, params: PPCAParams) -> PPCAParams:
    """Standard (unconstrained) M-step."""
    Ez, Ezz = stats
    n, d = x.shape
    mu = jnp.mean(x - Ez @ params.W.T, axis=0)
    xc = x - mu[None, :]
    W = jnp.linalg.solve(Ezz.sum(0), (xc.T @ Ez).T).T          # [D, M]
    s = (jnp.sum(xc * xc)
         - 2.0 * jnp.sum((xc @ W) * Ez)
         + jnp.sum(Ezz * (W.T @ W)[None]))
    a = (n * d) / jnp.maximum(s, 1e-12)
    return PPCAParams(W=W, mu=mu, a=a)


def nll(params: PPCAParams, x: jax.Array) -> jax.Array:
    """Exact negative log-likelihood under C = W W^T + a^{-1} I.

    Uses the Woodbury/determinant-lemma forms so cost is O(N D M + M^3),
    stable for D up to thousands (the SfM transposed layout has D = #points).
    """
    W, mu, a = params
    n, d = x.shape
    m = W.shape[1]
    eye_m = jnp.eye(m, dtype=W.dtype)
    Mmat = W.T @ W + eye_m / a                    # [M, M]
    # log|C| = -D log a + log|I + a W^T W| = -(D-M) log a + log|M_mat| ... :
    #   |C| = a^{-(D-M)} |W^T W + a^{-1} I|
    sign, logdet_M = jnp.linalg.slogdet(Mmat)
    logdet_C = -(d - m) * jnp.log(a) + logdet_M
    xc = x - mu[None, :]
    # tr(C^{-1} S_total):  C^{-1} = a (I - W Mmat^{-1} W^T)
    xW = xc @ W                                    # [N, M]
    sol = jnp.linalg.solve(Mmat, xW.T).T           # [N, M]
    quad = a * (jnp.sum(xc * xc) - jnp.sum(xW * sol))
    return 0.5 * (n * d * jnp.log(2.0 * jnp.pi) + n * logdet_C + quad)


@partial(jax.jit, static_argnames=("max_iters",))
def fit_em(params: PPCAParams, x: jax.Array, max_iters: int = 200
           ) -> tuple[PPCAParams, jax.Array]:
    """Plain EM to convergence-ish (fixed iteration budget, jit-scanned)."""

    def body(p, _):
        p = m_step(e_step(p, x), x, p)
        return p, nll(p, x)

    params, trace = jax.lax.scan(body, params, None, length=max_iters)
    return params, trace


def fit_svd(x: jax.Array, m: int) -> PPCAParams:
    """Closed-form ML solution (Tipping & Bishop Thm): the global optimum."""
    n, d = x.shape
    mu = x.mean(0)
    xc = x - mu[None]
    # economy SVD of the centered data
    _, s, vt = jnp.linalg.svd(xc, full_matrices=False)
    evals = (s * s) / n                             # eigenvalues of S
    sigma2 = jnp.sum(evals[m:]) / jnp.maximum(d - m, 1)
    W = vt[:m].T * jnp.sqrt(jnp.maximum(evals[:m] - sigma2, 0.0))[None, :]
    return PPCAParams(W=W, mu=mu, a=1.0 / jnp.maximum(sigma2, 1e-12))


def subspace_angle(Wa: jax.Array, Wb: jax.Array) -> jax.Array:
    """Largest principal angle (radians) between span(Wa) and span(Wb)."""
    qa, _ = jnp.linalg.qr(Wa)
    qb, _ = jnp.linalg.qr(Wb)
    s = jnp.linalg.svd(qa.T @ qb, compute_uv=False)
    return jnp.arccos(jnp.clip(jnp.min(s), -1.0, 1.0))
