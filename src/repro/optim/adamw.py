"""AdamW with optional Adafactor-style factored second moment.

No optax in this container — built from scratch. The factored mode keeps the
second moment as per-row/per-column statistics (rank-1 reconstruction) for
matrices, cutting optimizer memory from 2x params to ~1x + eps; required to
fit the 1T-param MoE on a single pod (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    factored: bool = False          # Adafactor-style factored v
    moment_dtype: Any = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any          # full v, or (v_row, v_col) tuples for factored matrices


def _is_factorable(x) -> bool:
    return x.ndim >= 2 and x.shape[-1] > 1 and x.shape[-2] > 1


def init(cfg: AdamWConfig, params: Any) -> AdamWState:
    m = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, cfg.moment_dtype), params)
    if cfg.factored:
        def init_v(p):
            if _is_factorable(p):
                return (jnp.zeros(p.shape[:-1], jnp.float32),
                        jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
            return jnp.zeros(p.shape, jnp.float32)
        v = jax.tree_util.tree_map(init_v, params)
    else:
        v = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, cfg.moment_dtype), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def update(cfg: AdamWConfig, state: AdamWState, params: Any, grads: Any,
           lr_scale: jax.Array | float = 1.0
           ) -> tuple[Any, AdamWState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        if isinstance(v, tuple):                       # factored second moment
            v_row, v_col = v
            g2 = g * g + 1e-30
            v_row = cfg.b2 * v_row + (1 - cfg.b2) * g2.mean(axis=-1)
            v_col = cfg.b2 * v_col + (1 - cfg.b2) * g2.mean(axis=-2)
            # rank-1 reconstruction: v ~ row x col / mean(row)
            denom = jnp.maximum(v_row.mean(axis=-1, keepdims=True), 1e-30)
            v_hat = (v_row[..., None] * v_col[..., None, :]
                     / denom[..., None])
            v_out = (v_row, v_col)
        else:
            v_hat = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
            v_out = v_hat.astype(v.dtype)
        upd_dir = (m_new / bc1) / (jnp.sqrt(v_hat / bc2) + cfg.eps)
        p_new = p.astype(jnp.float32) - lr * (upd_dir
                                              + cfg.weight_decay
                                              * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_out

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics


def abstract_state(cfg: AdamWConfig, abstract_params: Any) -> AdamWState:
    """ShapeDtypeStruct mirror of init() for the dry-run."""
    m = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, cfg.moment_dtype),
        abstract_params)
    if cfg.factored:
        def av(p):
            if len(p.shape) >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1:
                return (jax.ShapeDtypeStruct(p.shape[:-1], jnp.float32),
                        jax.ShapeDtypeStruct(p.shape[:-2] + p.shape[-1:],
                                             jnp.float32))
            return jax.ShapeDtypeStruct(p.shape, jnp.float32)
        v = jax.tree_util.tree_map(av, abstract_params)
    else:
        v = jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, cfg.moment_dtype),
            abstract_params)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=m, v=v)
