"""Consensus-ADMM distributed training — the paper's technique at LLM scale.

The mesh's ``pod`` axis carries the ADMM graph: each pod is one node i holding
its own full parameter replica theta_i (FSDP/TP-sharded *within* the pod).
Between consensus rounds each pod takes H local optimizer steps on its own
data shard (f_i = local loss). A consensus round then performs, entirely along
the pod axis (the scarce DCN tier):

  1. neighbor exchange of theta (circulant ppermute per graph offset,
     optionally int8-quantized — the dual update absorbs quantization error),
  2. objective probes f_i(theta_j) on a held-out probe batch (eq. 7 kappas),
  3. the proximal parameter pull + dual update (fused: one HBM pass),
  4. local residuals (eq. 5) and the per-edge penalty update (eq. 4/6/9/12)
     via the same ``repro.core.penalty`` engine the D-PPCA reproduction uses.

Compared to synchronous DP all-reduce every step, cross-pod traffic drops by
~H x and each edge's pull strength eta_ij adapts per the paper — the
"adaptive, dynamic network topology" of Fig. 1c realized on a TPU fabric.

Implementation: ``jax.shard_map`` manual over ``pod`` only; ``data``/``model``
stay auto so GSPMD handles within-pod parallelism (FSDP/TP/EP) untouched.
State leaves carry a leading node axis [J, ...] sharded P('pod', ...).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.graph import Graph, build_graph
from repro.core.penalty import (PenaltyConfig, PenaltyState,
                                init_penalty_state, update_penalty)
from repro.models.model import Model, arch_rules
from repro.distributed import sharding as shd
from repro.optim import adamw as adamw_lib


@dataclasses.dataclass(frozen=True)
class ConsensusConfig:
    penalty: PenaltyConfig = PenaltyConfig(scheme="nap", eta0=1.0)
    topology: str = "ring"         # circulant: ring | complete | expander
    local_steps: int = 8           # H — local optimizer steps per round
    prox_step: float = 0.5         # alpha in the prox pull (scaled by curv.)
    compression: str = "none"      # none | int8 — exchange quantization
    use_fused_kernel: bool = False  # Pallas consensus_update (TPU hot path)
    grad_rs: bool = False          # reduce-scatter grads to param shards


class TrainState(NamedTuple):
    params: Any            # [J, ...] per-node replicas, P('pod', ...)
    opt: adamw_lib.AdamWState
    lam: Any               # [J, ...] dual variables
    theta_bar_prev: Any    # [J, ...] neighbor mean at last round (eq. 5)
    penalty: PenaltyState  # [J, J] replicated
    step: jax.Array


def _leading(tree, spec_fn):
    """Map ParamDef-spec tree -> specs with leading 'pod' axis."""
    return jax.tree_util.tree_map(lambda s: P(*(("pod",) + tuple(s))),
                                  spec_fn)


class ConsensusTrainer:
    """Builds jit-able train_step / consensus_step for a model on a mesh."""

    def __init__(self, model: Model, mesh: Mesh, *,
                 adamw: adamw_lib.AdamWConfig, consensus: ConsensusConfig):
        self.model = model
        self.mesh = mesh
        self.acfg = adamw
        self.ccfg = consensus
        self.has_pod = mesh is not None and "pod" in mesh.axis_names
        self.num_nodes = int(mesh.shape["pod"]) if self.has_pod else 1
        self.graph: Graph = build_graph(consensus.topology, self.num_nodes) \
            if self.num_nodes > 1 else build_graph("complete", 1)
        self.offsets = (self.graph.neighbor_offsets_ring()
                        if self.num_nodes > 1 else [])
        # rules for *inside* the pod-manual region: batch maps to data only
        rules = arch_rules(model.cfg, mesh)
        rules["batch"] = ("data",)
        self.inner_rules = rules

    # ------------------------------------------------------------ state ----
    def _node_stack(self, tree):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.num_nodes,) + x.shape),
            tree)

    def init_state(self, key: jax.Array) -> TrainState:
        with shd.use_mesh(self.mesh, self.inner_rules):
            params1 = self.model.init(key)
        params = self._node_stack(params1)
        opt1 = adamw_lib.init(self.acfg, params1)
        opt = adamw_lib.AdamWState(step=opt1.step,
                                   m=self._node_stack(opt1.m),
                                   v=self._node_stack(opt1.v))
        zeros = jax.tree_util.tree_map(
            lambda x: jnp.zeros_like(x, jnp.float32), params)
        return TrainState(
            params=params, opt=opt, lam=zeros, theta_bar_prev=zeros,
            penalty=init_penalty_state(self.ccfg.penalty, self.num_nodes),
            step=jnp.zeros((), jnp.int32))

    def abstract_state(self) -> TrainState:
        """ShapeDtypeStruct mirror for the dry-run (no allocation)."""
        ap = self.model.abstract_params()

        def stack(tree):
            return jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(
                    (self.num_nodes,) + s.shape, s.dtype), tree)

        params = stack(ap)
        opt1 = adamw_lib.abstract_state(self.acfg, ap)
        opt = adamw_lib.AdamWState(step=opt1.step, m=stack(opt1.m),
                                   v=stack(opt1.v))
        zeros = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params)
        pen = init_penalty_state(self.ccfg.penalty, self.num_nodes)
        pen = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), pen)
        return TrainState(params=params, opt=opt, lam=zeros,
                          theta_bar_prev=zeros, penalty=pen,
                          step=jax.ShapeDtypeStruct((), jnp.int32))

    def state_shardings(self) -> TrainState:
        """NamedShardings for every state leaf (pod-leading params etc.)."""
        mesh = self.mesh
        with shd.use_mesh(mesh, self.inner_rules):
            pspec = self.model.param_specs()

        def lead(tree):
            return jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, P(*(("pod",) + tuple(s)))),
                tree, is_leaf=lambda s: isinstance(s, P))

        params_sh = lead(pspec)

        def like_params(tree_of_specs):
            return tree_of_specs

        opt_m = lead(pspec)
        ap = self.model.abstract_params()
        if self.acfg.factored:
            # factored leaves mirror param spec minus trailing dims;
            # factorability decided by SHAPE (mirror adamw._is_factorable)
            def fv(s, p):
                s = tuple(s)
                if len(p.shape) >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1:
                    return (NamedSharding(mesh, P(*(("pod",) + s[:-1]))),
                            NamedSharding(mesh,
                                          P(*(("pod",) + s[:-2] + s[-1:]))))
                return NamedSharding(mesh, P(*(("pod",) + s)))
            opt_v = jax.tree_util.tree_map(
                fv, pspec, ap, is_leaf=lambda s: isinstance(s, P))
        else:
            opt_v = lead(pspec)
        rep = NamedSharding(mesh, P())
        pen = jax.tree_util.tree_map(lambda _: rep,
                                     init_penalty_state(self.ccfg.penalty,
                                                        self.num_nodes))
        return TrainState(
            params=params_sh,
            opt=adamw_lib.AdamWState(step=rep, m=opt_m, v=opt_v),
            lam=lead(pspec), theta_bar_prev=lead(pspec),
            penalty=pen, step=rep)

    # ------------------------------------------------------- local steps ----
    def _local_loss(self, params, batch):
        with shd.use_mesh(self.mesh, self.inner_rules):
            loss, metrics = self.model.loss(params, batch)
        return loss, metrics

    def train_step(self, state: TrainState, batch: Any
                   ) -> tuple[TrainState, dict]:
        """One local optimizer step on every node (no cross-pod traffic)."""
        if not self.has_pod:
            def step1(params, opt, batch):
                (loss, _), grads = jax.value_and_grad(
                    self._local_loss, has_aux=True)(params, batch)
                p, o, m = adamw_lib.update(self.acfg, opt, params, grads)
                return p, o, loss, m["grad_norm"]

            p1 = jax.tree_util.tree_map(lambda x: x[0], state.params)
            o1 = adamw_lib.AdamWState(
                step=state.opt.step,
                m=jax.tree_util.tree_map(lambda x: x[0], state.opt.m),
                v=jax.tree_util.tree_map(lambda x: x[0], state.opt.v))
            b1 = jax.tree_util.tree_map(lambda x: x[0], batch)
            p, o, loss, gn = step1(p1, o1, b1)
            new = state._replace(
                params=jax.tree_util.tree_map(lambda x: x[None], p),
                opt=adamw_lib.AdamWState(
                    step=o.step,
                    m=jax.tree_util.tree_map(lambda x: x[None], o.m),
                    v=jax.tree_util.tree_map(lambda x: x[None], o.v)),
                step=state.step + 1)
            return new, {"loss": loss, "grad_norm": gn}

        # vmap over the node axis: per-node loss/grad/update with NO cross-pod
        # communication (GSPMD shards the leading axis on 'pod'). vmap is
        # preferred over pod-manual shard_map — see consensus_step docstring.
        # MoE archs fall back to a sequential per-node loop (the inner EP
        # shard_map has no vmap batching rule); a production multi-pod MoE
        # deployment runs per-pod controllers instead (DESIGN.md §5).
        def one_node(params, m, v, opt_step, batch):
            (loss, _), grads = jax.value_and_grad(
                self._local_loss, has_aux=True)(params, batch)
            if self.ccfg.grad_rs:
                with shd.use_mesh(self.mesh, self.inner_rules):
                    pspec = self.model.param_specs()
                grads = jax.tree_util.tree_map(
                    lambda g, s: jax.lax.with_sharding_constraint(
                        g, NamedSharding(self.mesh, s)),
                    grads, pspec)
            opt = adamw_lib.AdamWState(step=opt_step, m=m, v=v)
            p_new, opt_new, mtr = adamw_lib.update(self.acfg, opt, params,
                                                   grads)
            return p_new, opt_new.m, opt_new.v, loss, mtr["grad_norm"]

        if self.model.cfg.moe is not None:
            outs = []
            for i in range(self.num_nodes):
                sl = lambda t: jax.tree_util.tree_map(lambda x: x[i], t)
                outs.append(one_node(sl(state.params), sl(state.opt.m),
                                     sl(state.opt.v), state.opt.step,
                                     sl(batch)))
            stack = lambda k: jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *[o[k] for o in outs])
            p_new, m_new, v_new = stack(0), stack(1), stack(2)
            loss = jnp.stack([o[3] for o in outs])
            gn = jnp.stack([o[4] for o in outs])
        else:
            p_new, m_new, v_new, loss, gn = jax.vmap(
                one_node, in_axes=(0, 0, 0, None, 0))(
                state.params, state.opt.m, state.opt.v, state.opt.step,
                batch)
        new = state._replace(
            params=p_new,
            opt=adamw_lib.AdamWState(step=state.opt.step + 1, m=m_new,
                                     v=v_new),
            step=state.step + 1)
        return new, {"loss": loss.mean(), "grad_norm": gn}

    # --------------------------------------------------- consensus round ----
    def _encode_wire(self, tree):
        """Quantize for the exchange. The int8 payload (+ scalar scale) is
        what actually crosses pods — dequantization happens post-roll, so
        the collective-permute moves 1 byte/param instead of 2-4."""
        if self.ccfg.compression != "int8":
            return tree

        def q(x):
            axes = tuple(range(1, x.ndim))          # per-node absmax scale
            scale = (jnp.maximum(jnp.abs(x.astype(jnp.float32)).max(
                axis=axes, keepdims=True), 1e-12) / 127.0)
            xq = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                          -127, 127).astype(jnp.int8)
            return {"q": xq, "scale": scale}

        return jax.tree_util.tree_map(q, tree)

    def _decode_wire(self, tree, like):
        if self.ccfg.compression != "int8":
            return tree
        return jax.tree_util.tree_map(
            lambda enc, ref: (enc["q"].astype(jnp.float32)
                              * enc["scale"]).astype(ref.dtype),
            tree, like, is_leaf=lambda x: isinstance(x, dict) and "q" in x)

    def consensus_step(self, state: TrainState, probe_batch: Any
                       ) -> tuple[TrainState, dict]:
        """One ADMM consensus round along the pod axis.

        Implemented with ``jnp.roll`` on the pod-sharded node axis (GSPMD
        lowers it to collective-permute across pods) plus vmapped objective
        probes — no partial-manual shard_map here: the XLA SPMD partitioner
        miscompiles GSPMD-inside-manual at 512 devices (crash in
        spmd_partitioner_util.cc), and the roll/vmap formulation expresses
        the same communication pattern.
        """
        if self.num_nodes <= 1:
            return state, {"r_max": jnp.zeros(()), "eta_mean": jnp.asarray(
                self.ccfg.penalty.eta0)}
        j = self.num_nodes
        offsets = self.offsets
        adj = jnp.asarray(self.graph.adj)
        pcfg = self.ccfg.penalty
        idx = jnp.arange(j)

        # MoE blocks carry an inner expert-parallel shard_map, which XLA
        # cannot batch under vmap — probe those sequentially per node
        # (plain GSPMD forwards; J and degree are small).
        sequential = self.model.cfg.moe is not None

        def vloss(params, batch):
            if sequential:
                outs = []
                for i in range(j):
                    p_i = jax.tree_util.tree_map(lambda x: x[i], params)
                    b_i = jax.tree_util.tree_map(lambda x: x[i], batch)
                    outs.append(self._local_loss(p_i, b_i)[0])
                return jnp.stack(outs)
            return jax.vmap(lambda p, b: self._local_loss(p, b)[0])(
                params, batch)

        # probe own objective (pre-update params, eq. 7 semantics)
        f_self = vloss(state.params, probe_batch)              # [J]

        theta_wire = self._encode_wire(state.params)
        eta = state.penalty.eta
        sym_sum = jnp.zeros((j,), jnp.float32)
        nbr_w = None
        nbr_plain = None
        f_nbr = jnp.zeros((j, j), jnp.float32)
        for off in offsets:
            # rolled[i] = theta_{(i+off) % j}: one collective-permute on pod
            rolled = jax.tree_util.tree_map(
                lambda x: jnp.roll(x, -off, axis=0), theta_wire)
            rolled = self._decode_wire(rolled, state.params)
            jidx = (idx + off) % j
            f_off = vloss(rolled, probe_batch)                 # [J]
            f_nbr = f_nbr.at[idx, jidx].set(f_off)
            e_sym = 0.5 * (eta[idx, jidx] + eta[jidx, idx])    # [J]
            sym_sum = sym_sum + e_sym

            def wsum(a, scale=e_sym):
                bshape = (j,) + (1,) * (a.ndim - 1)
                return a.astype(jnp.float32) * scale.reshape(bshape)

            addw = jax.tree_util.tree_map(wsum, rolled)
            nbr_w = addw if nbr_w is None else jax.tree_util.tree_map(
                jnp.add, nbr_w, addw)
            addp = jax.tree_util.tree_map(
                lambda a: a.astype(jnp.float32), rolled)
            nbr_plain = addp if nbr_plain is None else \
                jax.tree_util.tree_map(jnp.add, nbr_plain, addp)

        deg = float(len(offsets))
        theta_bar = jax.tree_util.tree_map(lambda a: a / deg, nbr_plain)

        def per_node(v, a):
            return v.reshape((j,) + (1,) * (a.ndim - 1))

        nbr_avg = jax.tree_util.tree_map(
            lambda a: a / per_node(jnp.maximum(sym_sum, 1e-12), a), nbr_w)

        # -- prox pull + dual update + residuals (eq. 5) -------------------
        alpha = self.ccfg.prox_step / (1.0 + 2.0 * sym_sum)    # [J]
        eta_node = sym_sum / deg
        r_sq = jnp.zeros((j,), jnp.float32)
        s_sq = jnp.zeros((j,), jnp.float32)
        th_out, lam_out = [], []
        tdef = jax.tree_util.tree_structure(state.params)
        for th, lm, ba, bp, av in zip(
                jax.tree_util.tree_leaves(state.params),
                jax.tree_util.tree_leaves(state.lam),
                jax.tree_util.tree_leaves(theta_bar),
                jax.tree_util.tree_leaves(state.theta_bar_prev),
                jax.tree_util.tree_leaves(nbr_avg)):
            if self.ccfg.use_fused_kernel:
                from repro.kernels import ops as kops
                tn, ln, rs, ss = jax.vmap(
                    lambda t, l, a_, b_, p_, es, en, st: kops.consensus_update(
                        t.reshape(-1), l.reshape(-1), a_.reshape(-1),
                        b_.reshape(-1), p_.reshape(-1), eta_sum=es,
                        eta_node=en, step_size=st,
                        block_size=int(np.prod(th.shape[1:]))))(
                    th, lm, av, ba, bp, sym_sum, eta_node, alpha)
                tn = tn.reshape(th.shape)
                ln = ln.reshape(lm.shape)
            else:
                t32 = th.astype(jnp.float32)
                l32 = lm.astype(jnp.float32)
                es = per_node(sym_sum, th)
                tn = t32 - per_node(alpha, th) * (2.0 * l32
                                                  + es * (t32 - av))
                ln = l32 + 0.5 * es * (tn - av)
                axes = tuple(range(1, th.ndim))
                rs = jnp.sum((tn - ba) ** 2, axis=axes)
                ss = (eta_node ** 2) * jnp.sum((ba - bp) ** 2, axis=axes)
            th_out.append(tn.astype(th.dtype))
            lam_out.append(ln)
            r_sq, s_sq = r_sq + rs, s_sq + ss

        params_new = jax.tree_util.tree_unflatten(tdef, th_out)
        lam_new = jax.tree_util.tree_unflatten(tdef, lam_out)
        bar_new = theta_bar
        r_norm = jnp.sqrt(r_sq)
        s_norm = jnp.sqrt(s_sq)

        penalty_new = update_penalty(
            pcfg, state.penalty, adj=adj, f_self=f_self, f_nbr=f_nbr,
            r_norm=r_norm, s_norm=s_norm)
        new = state._replace(params=params_new, lam=lam_new,
                             theta_bar_prev=bar_new, penalty=penalty_new)
        metrics = {
            "r_max": r_norm.max(), "s_max": s_norm.max(),
            "f_mean": f_self.mean(),
            "eta_mean": jnp.where(adj, penalty_new.eta, 0.0).sum()
            / jnp.maximum(adj.sum(), 1),
        }
        return new, metrics

    # ------------------------------------------------------------ driver ----
    def should_sync(self, step: int) -> bool:
        return self.num_nodes > 1 and (step + 1) % self.ccfg.local_steps == 0
