"""Consensus-ADMM distributed training — the paper's technique at LLM scale.

The mesh's ``pod`` axis carries the ADMM graph: each pod is one node i holding
its own full parameter replica theta_i (FSDP/TP-sharded *within* the pod).
Between consensus rounds each pod takes H local optimizer steps on its own
data shard (f_i = local loss). A consensus round then performs, entirely along
the pod axis (the scarce DCN tier):

  1. neighbor exchange of theta (circulant ppermute per graph offset,
     optionally quantized through a pluggable wire codec — int8 per-leaf or
     fp8 per-block, ``repro.wire`` — the dual update absorbs the error),
  2. objective probes f_i(theta_j) on a held-out probe batch (eq. 7 kappas),
  3. the proximal parameter pull + dual update (fused: one HBM pass),
  4. local residuals (eq. 5) and the per-edge penalty update (eq. 4/6/9/12)
     via the same ``repro.core.penalty`` engine the D-PPCA reproduction uses.

Compared to synchronous DP all-reduce every step, cross-pod traffic drops by
~H x and each edge's pull strength eta_ij adapts per the paper — the
"adaptive, dynamic network topology" of Fig. 1c realized on a TPU fabric.

Implementation: the round runs on the flat-buffer engine (``optim.flatten``,
``docs/consensus_engine.md``): params pack into one [J, total] buffer
(leading node axis sharded P('pod', ...)), the exchange is ``jnp.roll`` on
the node axis (GSPMD lowers it to one collective-permute per graph offset),
and the fused update is a single Pallas call inside a shard_map that is
manual over ALL mesh axes. No partial-manual regions: GSPMD-inside-manual
miscompiles at 512 devices (spmd_partitioner_util.cc crash), so everything
else stays plain GSPMD with data/model auto (FSDP/TP/EP untouched).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.async_exec.ledger import AsyncConfig, WireLedger, init_wire_ledger
from repro.core.graph import Graph, build_graph
from repro.core.penalty import (PenaltyConfig, PenaltyState, effective_eta,
                                freeze_penalty, init_penalty_state,
                                update_penalty)
from repro.models.model import Model, arch_rules
from repro.distributed import sharding as shd
from repro.kernels import ref as kref
from repro.obs import node_ring as obs_node_ring
from repro.obs import ring as obs_ring
from repro.obs import schema as obs_schema
from repro.obs import trace as obs_trace
from repro.obs.ring import ObsConfig
from repro.optim import adamw as adamw_lib
from repro.optim import flatten
from repro.topology import (TopologyConfig, TopologyRuntime, TopologyState,
                            active_edge_fraction, compose_mask, sym_age,
                            tick_age)
from repro import wire as wire_lib


@dataclasses.dataclass(frozen=True)
class ConsensusConfig:
    penalty: PenaltyConfig = PenaltyConfig(scheme="nap", eta0=1.0)
    topology: str = "ring"         # circulant: ring | complete | expander
    local_steps: int = 8           # H — local optimizer steps per round
    prox_step: float = 0.5         # alpha in the prox pull (scaled by curv.)
    compression: str = "none"      # legacy spelling: none | int8
    # wire codec for the consensus exchange (repro.wire): native | int8 |
    # fp8_e4m3 | fp8_e5m2. Empty resolves from `compression` ("none" ->
    # native), keeping the legacy knob working; a non-empty value wins.
    wire_codec: str = ""
    use_fused_kernel: bool = True  # Pallas consensus_round (interpret on CPU)
    block_size: int = 0            # flat-layout block; 0 => auto
    grad_rs: bool = False          # reduce-scatter grads to param shards
    # shard the flat consensus state (lam / theta_bar_prev / wire / ledger)
    # over the in-pod mesh axes: P('pod', ('data', 'model', ...)). Each
    # device then runs the fused kernel on only its flat-axis slab and
    # per-device consensus-state HBM shrinks by the in-pod axis size.
    # False keeps the PR 1-3 replicated-in-pod path byte-identical.
    shard_consensus: bool = False
    # dynamic-topology runtime (repro.topology): the default static
    # scheduler without churn keeps the engine on the exact PR 1 code path
    dyn_topology: TopologyConfig = TopologyConfig()
    # bounded-staleness async executor (repro.async_exec): None keeps the
    # trainer strictly synchronous; max_staleness=0 enables the async step
    # functions but waits for every payload (bit-identical to sync)
    async_exec: AsyncConfig | None = None
    # latency-hiding round pipeline: how many graph offsets' collective-
    # permutes may be in flight ahead of the decode/probe consume point.
    # 1 (default) is the strictly sequential permute-then-consume loop;
    # >= 2 issues permutes early behind optimization_barriers, landing
    # them in the WireLedger double buffer, and consumes them in offset
    # order — numerically bit-identical at every depth (pinned), the
    # depth only widens the window the latency-hiding scheduler may
    # overlap. Pair with launch.mesh.set_backend_flags().
    pipeline_offsets: int = 1
    # observability (repro.obs): the on-device metrics ring + trace spans.
    # None (and ObsConfig(enabled=False)) leaves the compiled step
    # byte-identical to a build without the subsystem
    obs: ObsConfig | None = None


class TrainState(NamedTuple):
    params: Any            # [J, ...] per-node replicas, P('pod', ...)
    opt: adamw_lib.AdamWState
    lam: jax.Array         # [J, total] flat dual buffer (FlatLayout)
    theta_bar_prev: jax.Array  # [J, total] flat neighbor mean (eq. 5)
    penalty: PenaltyState  # [J, J] replicated
    step: jax.Array
    topo: TopologyState    # [J, J] replicated — dynamic-topology runtime
    ledger: Any = None     # WireLedger [deg, J, W] — async executor only
    ring: Any = None       # obs.MetricsRing [cap, n_metrics] — obs only
    node_ring: Any = None  # obs.NodeRing [cap, J, n_node_cols] — obs only


def _leading(tree, spec_fn):
    """Map ParamDef-spec tree -> specs with leading 'pod' axis."""
    return jax.tree_util.tree_map(lambda s: P(*(("pod",) + tuple(s))),
                                  spec_fn)


class ConsensusTrainer:
    """Builds jit-able train_step / consensus_step for a model on a mesh."""

    def __init__(self, model: Model, mesh: Mesh, *,
                 adamw: adamw_lib.AdamWConfig, consensus: ConsensusConfig):
        self.model = model
        self.mesh = mesh
        self.acfg = adamw
        self.ccfg = consensus
        self.has_pod = mesh is not None and "pod" in mesh.axis_names
        self.num_nodes = int(mesh.shape["pod"]) if self.has_pod else 1
        self.graph: Graph = build_graph(consensus.topology, self.num_nodes) \
            if self.num_nodes > 1 else build_graph("complete", 1)
        # dynamic-topology runtime: offsets come from ITS superset (equal to
        # the graph's circulant offsets unless churn adds spare offsets)
        self.topo_cfg = consensus.dyn_topology
        self.topo_cfg.validate_penalty(consensus.penalty)
        self.topo_rt = TopologyRuntime(self.graph, self.topo_cfg)
        self.dynamic = self.topo_cfg.is_dynamic and self.num_nodes > 1
        self.offsets = self.topo_rt.offsets if self.num_nodes > 1 else []
        # async executor (repro.async_exec): staleness gating engages the
        # masked kernel path even under a static scheduler
        self.async_cfg = consensus.async_exec
        # latency-hiding round pipeline (docs/consensus_engine.md "Round
        # pipeline"): depth 1 keeps the exact sequential loop; >= 2 issues
        # offset permutes early and lands them in the WireLedger, which
        # the sync path then carries too (needs_ledger)
        self.pipeline_depth = max(1, int(consensus.pipeline_offsets))
        self.pipelined = self.pipeline_depth > 1 and self.num_nodes > 1
        self.needs_ledger = self.num_nodes > 1 \
            and (self.async_cfg is not None or self.pipelined)
        # rules for *inside* the pod-manual region: batch maps to data only
        rules = arch_rules(model.cfg, mesh)
        rules["batch"] = ("data",)
        self.inner_rules = rules
        # in-pod sharding of the flat consensus state: one shard per device
        # position on the non-pod mesh axes (the engine's shard grid)
        self.inner_axes, inner_size = shd.inpod_axes(
            mesh if self.has_pod else None)
        self.sharded = bool(consensus.shard_consensus) \
            and self.num_nodes > 1 and inner_size > 1
        self.n_shards = inner_size if self.sharded else 1
        # static flat-buffer layout for the consensus engine (shards=1 is
        # byte-identical to the unsharded PR 1 layout)
        ap = model.abstract_params()
        bs = consensus.block_size or flatten.auto_block_size(ap)
        self.layout = flatten.FlatLayout.for_tree(ap, block_size=bs,
                                                  node_axis=False,
                                                  shards=self.n_shards)
        self.slayout = self.layout.shard(self.n_shards) if self.sharded \
            else None
        # the pluggable wire codec (repro.wire) every wire producer and
        # consumer goes through: trainer encode/decode, ledger row sizing,
        # kernel dequant granularity, probe-side unpack
        self.codec_name = wire_lib.resolve_codec_name(
            consensus.wire_codec or consensus.compression)
        self.codec = wire_lib.get_codec(self.codec_name, self.layout,
                                        self.slayout)
        self.dequant_spec = self.codec.kernel_dequant_spec()
        # observability (repro.obs): the metrics ring rides in TrainState
        # and trace spans wrap the round phases — both fully gated, so an
        # obs-off trainer lowers byte-identical HLO (tests/test_obs.py)
        self.obs_cfg = consensus.obs
        self.obs_on = self.obs_cfg is not None and self.obs_cfg.enabled
        self.node_ring_on = self.obs_on and self.obs_cfg.with_node_ring
        self._span = obs_trace.span_factory(
            self.obs_on and self.obs_cfg.with_spans)

    # ------------------------------------------------------------ state ----
    def _node_stack(self, tree):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.num_nodes,) + x.shape),
            tree)

    def init_state(self, key: jax.Array) -> TrainState:
        with shd.use_mesh(self.mesh, self.inner_rules):
            params1 = self.model.init(key)
        params = self._node_stack(params1)
        opt1 = adamw_lib.init(self.acfg, params1)
        opt = adamw_lib.AdamWState(step=opt1.step,
                                   m=self._node_stack(opt1.m),
                                   v=self._node_stack(opt1.v))
        # two distinct buffers (never aliased: the state may be donated)
        flat_shape = (self.num_nodes, self.layout.total)
        ledger = None
        if self.needs_ledger:
            ledger = init_wire_ledger(self.layout, len(self.offsets),
                                      self.num_nodes, codec=self.codec)
        return TrainState(
            params=params, opt=opt,
            lam=jnp.zeros(flat_shape, jnp.float32),
            theta_bar_prev=jnp.zeros(flat_shape, jnp.float32),
            penalty=init_penalty_state(self.ccfg.penalty, self.num_nodes),
            step=jnp.zeros((), jnp.int32),
            topo=self.topo_rt.init_state(),
            ledger=ledger,
            ring=(obs_ring.init_ring(self.obs_cfg.ring_capacity)
                  if self.obs_on else None),
            node_ring=(obs_node_ring.init_node_ring(
                self.obs_cfg.ring_capacity, self.num_nodes)
                if self.node_ring_on else None))

    def abstract_state(self) -> TrainState:
        """ShapeDtypeStruct mirror for the dry-run (no allocation)."""
        ap = self.model.abstract_params()

        def stack(tree):
            return jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(
                    (self.num_nodes,) + s.shape, s.dtype), tree)

        params = stack(ap)
        opt1 = adamw_lib.abstract_state(self.acfg, ap)
        opt = adamw_lib.AdamWState(step=opt1.step, m=stack(opt1.m),
                                   v=stack(opt1.v))
        flat0 = jax.ShapeDtypeStruct((self.num_nodes, self.layout.total),
                                     jnp.float32)
        pen = init_penalty_state(self.ccfg.penalty, self.num_nodes)
        pen = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), pen)
        topo = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            self.topo_rt.init_state())
        ledger = None
        if self.needs_ledger:
            ledger = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                init_wire_ledger(self.layout, len(self.offsets),
                                 self.num_nodes, codec=self.codec))
        ring = None
        if self.obs_on:
            ring = obs_ring.MetricsRing(
                buf=jax.ShapeDtypeStruct(
                    (self.obs_cfg.ring_capacity, obs_schema.NUM_COLUMNS),
                    jnp.float32),
                head=jax.ShapeDtypeStruct((), jnp.int32))
        node_ring = None
        if self.node_ring_on:
            node_ring = obs_node_ring.NodeRing(
                buf=jax.ShapeDtypeStruct(
                    (self.obs_cfg.ring_capacity, self.num_nodes,
                     obs_schema.NUM_NODE_COLUMNS), jnp.float32),
                head=jax.ShapeDtypeStruct((), jnp.int32))
        return TrainState(params=params, opt=opt, lam=flat0,
                          theta_bar_prev=flat0, penalty=pen,
                          step=jax.ShapeDtypeStruct((), jnp.int32),
                          topo=topo, ledger=ledger, ring=ring,
                          node_ring=node_ring)

    def state_shardings(self) -> TrainState:
        """NamedShardings for every state leaf (pod-leading params etc.)."""
        mesh = self.mesh
        with shd.use_mesh(mesh, self.inner_rules):
            pspec = self.model.param_specs()

        def lead(tree):
            return jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, P(*(("pod",) + tuple(s)))),
                tree, is_leaf=lambda s: isinstance(s, P))

        params_sh = lead(pspec)

        def like_params(tree_of_specs):
            return tree_of_specs

        opt_m = lead(pspec)
        ap = self.model.abstract_params()
        if self.acfg.factored:
            # factored leaves mirror param spec minus trailing dims;
            # factorability decided by SHAPE (mirror adamw._is_factorable)
            def fv(s, p):
                s = tuple(s)
                if len(p.shape) >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1:
                    return (NamedSharding(mesh, P(*(("pod",) + s[:-1]))),
                            NamedSharding(mesh,
                                          P(*(("pod",) + s[:-2] + s[-1:]))))
                return NamedSharding(mesh, P(*(("pod",) + s)))
            opt_v = jax.tree_util.tree_map(
                fv, pspec, ap, is_leaf=lambda s: isinstance(s, P))
        else:
            opt_v = lead(pspec)
        rep = NamedSharding(mesh, P())
        pen = jax.tree_util.tree_map(lambda _: rep,
                                     init_penalty_state(self.ccfg.penalty,
                                                        self.num_nodes))
        # flat buffers: node-sharded rows; with shard_consensus each pod's
        # row additionally splits over the in-pod axes (one slab per device
        # — see docs/consensus_engine.md "Sharded layout"), otherwise it is
        # replicated within the pod (the PR 1 path)
        flat_sh = NamedSharding(mesh, self._flat_pspec())
        topo_sh = jax.tree_util.tree_map(lambda _: rep,
                                         self.topo_rt.init_state())
        ledger_sh = None
        if self.needs_ledger:
            # wire rows shard like the stacked payloads in the fused round
            ledger_sh = WireLedger(
                wires=NamedSharding(mesh, self._flat_pspec(3)), round=rep,
                w_prev=rep)
        # the metrics rings are tiny ([cap, n_metrics] / [cap, J, n_cols]
        # f32) and read by the host drain: replicate them like the other
        # telemetry state (node-ring rows hold the POST-psum per-node
        # residuals, identical on every device by construction)
        ring_sh = obs_ring.MetricsRing(buf=rep, head=rep) \
            if self.obs_on else None
        node_ring_sh = obs_node_ring.NodeRing(buf=rep, head=rep) \
            if self.node_ring_on else None
        return TrainState(
            params=params_sh,
            opt=adamw_lib.AdamWState(step=rep, m=opt_m, v=opt_v),
            lam=flat_sh, theta_bar_prev=flat_sh,
            penalty=pen, step=rep, topo=topo_sh, ledger=ledger_sh,
            ring=ring_sh, node_ring=node_ring_sh)

    # ------------------------------------------------------- local steps ----
    def _local_loss(self, params, batch):
        with shd.use_mesh(self.mesh, self.inner_rules):
            loss, metrics = self.model.loss(params, batch)
        return loss, metrics

    def train_step(self, state: TrainState, batch: Any
                   ) -> tuple[TrainState, dict]:
        """One local optimizer step on every node (no cross-pod traffic)."""
        if not self.has_pod:
            def step1(params, opt, batch):
                (loss, _), grads = jax.value_and_grad(
                    self._local_loss, has_aux=True)(params, batch)
                p, o, m = adamw_lib.update(self.acfg, opt, params, grads)
                return p, o, loss, m["grad_norm"]

            p1 = jax.tree_util.tree_map(lambda x: x[0], state.params)
            o1 = adamw_lib.AdamWState(
                step=state.opt.step,
                m=jax.tree_util.tree_map(lambda x: x[0], state.opt.m),
                v=jax.tree_util.tree_map(lambda x: x[0], state.opt.v))
            b1 = jax.tree_util.tree_map(lambda x: x[0], batch)
            p, o, loss, gn = step1(p1, o1, b1)
            new = state._replace(
                params=jax.tree_util.tree_map(lambda x: x[None], p),
                opt=adamw_lib.AdamWState(
                    step=o.step,
                    m=jax.tree_util.tree_map(lambda x: x[None], o.m),
                    v=jax.tree_util.tree_map(lambda x: x[None], o.v)),
                step=state.step + 1)
            return new, {"loss": loss, "grad_norm": gn}

        # vmap over the node axis: per-node loss/grad/update with NO cross-pod
        # communication (GSPMD shards the leading axis on 'pod'). vmap is
        # preferred over pod-manual shard_map — see consensus_step docstring.
        # MoE archs fall back to a sequential per-node loop (the inner EP
        # shard_map has no vmap batching rule); a production multi-pod MoE
        # deployment runs per-pod controllers instead (DESIGN.md §5).
        def one_node(params, m, v, opt_step, batch):
            (loss, _), grads = jax.value_and_grad(
                self._local_loss, has_aux=True)(params, batch)
            if self.ccfg.grad_rs:
                with shd.use_mesh(self.mesh, self.inner_rules):
                    pspec = self.model.param_specs()
                grads = jax.tree_util.tree_map(
                    lambda g, s: jax.lax.with_sharding_constraint(
                        g, NamedSharding(self.mesh, s)),
                    grads, pspec)
            opt = adamw_lib.AdamWState(step=opt_step, m=m, v=v)
            p_new, opt_new, mtr = adamw_lib.update(self.acfg, opt, params,
                                                   grads)
            return p_new, opt_new.m, opt_new.v, loss, mtr["grad_norm"]

        if self.model.cfg.moe is not None:
            outs = []
            for i in range(self.num_nodes):
                sl = lambda t: jax.tree_util.tree_map(lambda x: x[i], t)
                outs.append(one_node(sl(state.params), sl(state.opt.m),
                                     sl(state.opt.v), state.opt.step,
                                     sl(batch)))
            stack = lambda k: jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *[o[k] for o in outs])
            p_new, m_new, v_new = stack(0), stack(1), stack(2)
            loss = jnp.stack([o[3] for o in outs])
            gn = jnp.stack([o[4] for o in outs])
        else:
            p_new, m_new, v_new, loss, gn = jax.vmap(
                one_node, in_axes=(0, 0, 0, None, 0))(
                state.params, state.opt.m, state.opt.v, state.opt.step,
                batch)
        new = state._replace(
            params=p_new,
            opt=adamw_lib.AdamWState(step=state.opt.step + 1, m=m_new,
                                     v=v_new),
            step=state.step + 1)
        return new, {"loss": loss.mean(), "grad_norm": gn}

    # --------------------------------------------------- consensus round ----
    def _probe_vloss(self):
        """Per-node objective probe function (shared by sync/async rounds).

        MoE blocks carry an inner expert-parallel shard_map, which XLA
        cannot batch under vmap — probe those sequentially per node
        (plain GSPMD forwards; J and degree are small).
        """
        j = self.num_nodes
        sequential = self.model.cfg.moe is not None

        def vloss(params, batch):
            if sequential:
                outs = []
                for i in range(j):
                    p_i = jax.tree_util.tree_map(lambda x: x[i], params)
                    b_i = jax.tree_util.tree_map(lambda x: x[i], batch)
                    outs.append(self._local_loss(p_i, b_i)[0])
                return jnp.stack(outs)
            return jax.vmap(lambda p, b: self._local_loss(p, b)[0])(
                params, batch)

        return vloss

    def _finish_round(self, new: TrainState, metrics: dict,
                      node_metrics: dict | None = None
                      ) -> tuple[TrainState, dict]:
        """Every consensus round's single exit: schema + metrics rings.

        Unifies the metrics dict to the full ``obs.schema.ROUND_METRICS``
        key set (sync, async, replicated and sharded rounds all emit
        IDENTICAL keys — pinned by tests/test_obs.py) and, with obs
        enabled, appends the round's row to the on-device metrics ring
        (one ``dynamic_update_slice``; the host drains every K rounds).
        ``node_metrics`` is the per-node dict of ``[J]`` vectors for the
        node ring (``obs.schema.NODE_METRICS``; missing keys pad to the
        defined not-applicable values) — appended the same way when
        ``ObsConfig.with_node_ring`` is on.
        """
        metrics = obs_schema.unify_round_metrics(metrics)
        if self.obs_on and new.ring is not None:
            row = obs_schema.metrics_row(new.step, metrics)
            new = new._replace(ring=obs_ring.ring_append(new.ring, row))
        if self.node_ring_on and new.node_ring is not None:
            nrow = obs_schema.node_row(new.step, node_metrics or {},
                                       self.num_nodes)
            new = new._replace(
                node_ring=obs_node_ring.node_ring_append(new.node_ring,
                                                         nrow))
        return new, metrics

    def _flat_pspec(self, ndim: int = 2) -> P:
        """THE spelling of the flat-buffer sharding, at any rank.

        ``[..., J, total]`` -> ``P(None, ..., 'pod', <in-pod axes>)`` when
        sharded, ``P(None, ..., 'pod', None)`` (replicated in-pod)
        otherwise. Every site that shards a flat buffer — state
        shardings, ledger rows, constraints, the fused-round shard_map
        specs — derives from here, so the scheme can only change in one
        place.
        """
        lead = (None,) * (ndim - 2)
        tail = self.inner_axes if self.sharded else None
        return P(*lead, "pod", tail)

    def _constrain_flat(self, x):
        """Pin a [J, total]-shaped value to the engine's flat sharding.

        Sharded mode only (a no-op otherwise): keeps GSPMD from choosing
        in-pod replication for the packed buffers between the pack/encode
        ops and the manual fused-round region.
        """
        if not self.sharded:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self._flat_pspec(x.ndim)))

    def _encode_wire(self, theta_flat):
        """Flat buffer -> the wire message the permutes move.

        One call into the configured codec (``repro.wire``): native passes
        the packed buffer through, int8/fp8 quantize with their scale
        bytes in-band. Sharded wires are per-shard self-contained slabs
        (see ``docs/wire_formats.md``), pinned to the engine's flat
        sharding so each device encodes only its slab.
        """
        with self._span("wire/encode"):
            wire = self.codec.encode(theta_flat)
        if self.sharded:
            return self._constrain_flat(wire)
        return wire

    def _decode_wire(self, wire):
        """Wire message -> (payload [J, total], scales [J, W] | None).

        ``W`` is the codec's scale width: num_leaves for the int8 tail,
        num_blocks for the fp8 per-block scales (which shard with the
        slabs — slab-local decode, no in-pod broadcast).
        """
        with self._span("wire/decode"):
            payload, scales = self.codec.decode(wire)
        if self.sharded:
            payload = self._constrain_flat(payload)
            if scales is not None and self.dequant_spec.per_block:
                scales = self._constrain_flat(scales)
        return payload, scales

    def _probe_params(self, payload, scales):
        """Decoded (payload, scales) -> the probe forward's param pytree.

        Sharded mode first pins the payload (and per-block scales) to an
        in-pod-REPLICATED sharding — ONE all-gather of the slab-resident
        buffer per offset — so the per-leaf unpack slices below are
        device-local. Without the pin, every leaf slice crossing a slab
        boundary pays its own in-pod resharding collective (the PR 4
        known cost, one per leaf per offset). Collective count pinned in
        tests/test_consensus_fused.py.
        """
        if self.sharded:
            rep = NamedSharding(self.mesh, P("pod", None))
            payload = jax.lax.with_sharding_constraint(payload, rep)
            if scales is not None and self.dequant_spec.per_block:
                scales = jax.lax.with_sharding_constraint(scales, rep)
        return self.codec.unpack(payload, scales)

    def _fused_round(self, theta_flat, lam_flat, bar_prev, wires, scales,
                     e_stack, alpha, sym_sum, eta_node,
                     bar_w=None, inv_deg=None, kick_w=None):
        """One shard_map'd Pallas call over the whole flat buffer.

        Manual over ALL mesh axes with nothing but the kernel inside — the
        historical GSPMD-inside-manual miscompile does not apply because the
        region contains no auto-sharded ops. Each device runs the kernel on
        its pod's node row: the whole row (replicated across the in-pod
        axes) by default, or — with ``shard_consensus`` — only its in-pod
        slab of the flat axis, with the per-shard block->leaf table riding
        as a traced operand and the blockwise residual partials finished by
        ONE psum over the in-pod axes.

        ``bar_w``/``inv_deg`` (dynamic topology) ride next to e_sym / the
        node scalars: the traced edge gates select the masked kernel.
        ``kick_w`` (zero-kick absorption for newly-gated edges) is one more
        [deg, J] operand next to the gates.
        """
        from repro.kernels import ops as kops

        lay = self.layout
        sharded = self.sharded
        inner = self.inner_axes
        masked = bar_w is not None
        kicked = kick_w is not None
        per_block = self.dequant_spec.per_block
        pod = P("pod")
        flat_spec = self._flat_pspec(2)
        wires_spec = self._flat_pspec(3)
        # per-leaf scale rows are replicated in-pod (global leaf ids);
        # per-block rows (fp8) shard with the slabs, so each device's
        # kernel reads its own blocks' scales at local block ids
        scales_spec = self._flat_pspec(3) if per_block \
            else P(None, "pod", None)

        # node scalars ride as one stacked [3|4, J] SMEM block; the traced
        # edge gates / kick weights (when present) are extra [deg, J]
        # operands; the sharded path appends its [n_shards, blocks/shard]
        # block->leaf table, sharded so each device reads its slab's row
        rows = [alpha, sym_sum, eta_node] + ([inv_deg] if masked else [])
        node_sc = jnp.stack(rows, axis=0)
        args = [theta_flat, lam_flat, bar_prev, wires, scales, e_stack] \
            + ([bar_w] if masked else []) + ([kick_w] if kicked else []) \
            + [node_sc]
        in_specs = (flat_spec, flat_spec, flat_spec,
                    wires_spec, scales_spec,
                    P(None, "pod")) \
            + ((P(None, "pod"),) if masked else ()) \
            + ((P(None, "pod"),) if kicked else ()) + (P(None, "pod"),)
        if sharded:
            args.append(jnp.asarray(self.slayout.block_leaf_shards,
                                    jnp.int32))
            in_specs += (P(inner, None),)

        def local(theta, lam, barp, w, s, e, *rest):
            rest = list(rest)
            bw = rest.pop(0) if masked else None
            kw = rest.pop(0) if kicked else None
            nsc = rest.pop(0)
            out = kops.consensus_round(
                theta, lam, barp, w, s, e, nsc[0], nsc[1], nsc[2],
                block_leaf=(None if sharded
                            else tuple(lay.block_leaf.tolist())),
                block_leaf_arr=rest.pop(0)[0] if sharded else None,
                block_size=lay.block_size,
                bar_w=bw, inv_deg=nsc[3] if masked else None, kick_w=kw,
                scales_per_block=per_block)
            if sharded:
                # finish the blockwise residual partials across the slab
                # grid: ONE psum over the in-pod axes per reduction
                tn, ln, bar, rsq, ssq = out
                out = (tn, ln, bar, jax.lax.psum(rsq, inner),
                       jax.lax.psum(ssq, inner))
            return out

        fn = shd.shard_map_compat(
            local, self.mesh, in_specs=in_specs,
            out_specs=(flat_spec, flat_spec, flat_spec, pod, pod))
        with self._span("consensus/fused_round"):
            return fn(*args)

    def consensus_step(self, state: TrainState, probe_batch: Any
                       ) -> tuple[TrainState, dict]:
        """One ADMM consensus round along the pod axis (flat-buffer engine).

        Per round: pack params once into the [J, total] wire buffer, then

          * exchange — ONE ``jnp.roll`` per graph offset on the pod-sharded
            node axis (GSPMD lowers it to a collective-permute of the whole
            contiguous buffer; int8 wire carries its bitcast scales in-band),
          * objective probes f_i(theta_j) on the held-out probe batch
            (eq. 7 kappas) straight off the rolled payloads,
          * ONE fused Pallas call (``kernels.consensus_round``) for
            dequant + neighbor means + prox pull + dual update + both
            residual reductions (eq. 5) — or the blockwise-identical jnp
            reference when ``use_fused_kernel=False``,
          * the per-edge penalty update (eq. 4/6/9/12) via
            ``repro.core.penalty``.

        No partial-manual shard_map around GSPMD ops: the XLA SPMD
        partitioner miscompiles GSPMD-inside-manual at 512 devices; the
        fused kernel runs under a fully-manual region instead.
        """
        if self.num_nodes <= 1:
            return self._finish_round(state, {
                "r_max": jnp.zeros(()),
                "eta_mean": jnp.asarray(self.ccfg.penalty.eta0)})
        j = self.num_nodes
        offsets = self.offsets
        deg = len(offsets)
        adj = jnp.asarray(self.graph.adj)
        pcfg = self.ccfg.penalty
        idx = jnp.arange(j)
        lay = self.layout
        dynamic = self.dynamic

        vloss = self._probe_vloss()

        # probe own objective (pre-update params, eq. 7 semantics)
        with self._span("consensus/probe"):
            f_self = vloss(state.params, probe_batch)          # [J]

        # pack in the params' native float dtype: the uncompressed wire then
        # moves the same bytes/param as the old per-leaf exchange (bf16 = 2B)
        with self._span("consensus/pack"):
            theta_flat = self._constrain_flat(
                lay.pack(state.params, dtype=lay.wire_dtype))
            wire = self._encode_wire(theta_flat)

        eta = state.penalty.eta
        ones = jnp.ones((j, self.dequant_spec.scale_width), jnp.float32)
        sym_sum = jnp.zeros((j,), jnp.float32)
        f_nbr = jnp.zeros((j, j), jnp.float32)
        payloads, scale_rows, e_rows = [], [], []
        topo = state.topo
        # scheduler zero-kick (engine side): consume the pending kick
        # weights stored when edges gated at the END of the last round —
        # their neighbors' parameters are on THIS round's wire
        kick_on = dynamic and self.topo_cfg.can_gate
        kick_rows = []
        if dynamic:
            mask_f = topo.mask.astype(jnp.float32)
            act = jnp.zeros((j,), jnp.float32)
            w_rows = []
            payload_dtype = self.codec.payload_dtype
        # per-node wire accounting for the node ring: offsets whose permute
        # ran AND whose payload this node consumed (mask or pending kick)
        rx = jnp.zeros((j,), jnp.float32) if self.node_ring_on else None

        # ---- pipelined issue phase (pipeline_offsets >= 2) ---------------
        # Reuse the async executor's WireLedger as the sync path's double
        # buffer: raw rolled wire rows are issued AHEAD of the consume
        # loop (up to `depth` permutes in flight before any decode/probe
        # work) and read back in offset order. Each issue past the first
        # window ties to the consume token of the offset `depth` earlier
        # through an optimization_barrier — a real data dependency that
        # bounds the in-flight window — and the latency-hiding scheduler
        # (launch.mesh.set_backend_flags) overlaps the permutes with the
        # earlier offsets' decode/probe compute. Bit-identical to the
        # sequential loop at every depth: only scheduling freedom grows.
        pipelined = self.pipelined
        skip_dead = dynamic and self.topo_cfg.skip_dead_offsets
        if pipelined:
            assert state.ledger is not None, \
                "init_state builds the wire ledger for pipeline_offsets>=2"
            depth = min(self.pipeline_depth, deg)
            inflight: list = [None] * deg
            needs: list = [None] * deg
            if skip_dead:
                for d0, off0 in enumerate(offsets):
                    jidx0 = (idx + off0) % j
                    m0 = mask_f[idx, jidx0]
                    needs[d0] = m0.sum() if not kick_on \
                        else m0.sum() + topo.kick[idx, jidx0].sum()

            def _issue_row(d, token=None):
                src = wire
                if token is not None:
                    src, _ = jax.lax.optimization_barrier((src, token))

                def _roll(src=src, off_d=offsets[d]):
                    # same barrier discipline as the sequential _exchange:
                    # pins the wire dtype; the span brackets the real wire
                    with self._span(f"consensus/exchange/off{off_d}"):
                        return jax.lax.optimization_barrier(
                            jnp.roll(src, -off_d, axis=0))

                if needs[d] is None:
                    return _roll()
                # dead-offset skip with the permute issued a step early:
                # hold last round's ledger row (never decoded — the dead
                # branch below skips the consume entirely)
                return jax.lax.cond(needs[d] > 0, _roll,
                                    lambda: state.ledger.wires[d])

            for d0 in range(depth):
                inflight[d0] = _issue_row(d0)

        for d, off in enumerate(offsets):
            jidx = (idx + off) % j

            def _exchange(d=d, off=off):
                if pipelined:
                    # consume the pre-issued row from the double buffer
                    payload, scales = self._decode_wire(inflight[d])
                else:
                    # rolled[i] = wire_{(i+off) % j}: ONE collective-
                    # permute on pod moving the whole contiguous buffer
                    # (payload + in-band scales). The barrier pins the
                    # exchange to the wire dtype — without it XLA hoists
                    # the consumers' f32 upcast above the permute and a
                    # bf16 wire would cross the DCN at 4 B/param.
                    with self._span(f"consensus/exchange/off{off}"):
                        rolled = jax.lax.optimization_barrier(
                            jnp.roll(wire, -off, axis=0))
                        payload, scales = self._decode_wire(rolled)
                with self._span("consensus/probe"):
                    f_off = vloss(self._probe_params(payload, scales),
                                  probe_batch)
                return payload, (ones if scales is None else scales), f_off

            if dynamic:
                m_off = mask_f[idx, jidx]                          # [J]
                k_off = topo.kick[idx, jidx] if kick_on else None
                if self.topo_cfg.skip_dead_offsets:
                    # an all-gated offset round skips its permute AND its
                    # probe at runtime; the mask is replicated so every
                    # device takes the same branch. The dead branch probes
                    # f_self (a no-op for the eq. 8 extremes). A pending
                    # zero-kick keeps the offset alive: the absorption term
                    # needs the gated neighbor's payload off the wire.
                    def _dead():
                        return (jnp.zeros((j, lay.total), payload_dtype),
                                ones, f_self)

                    need = needs[d] if pipelined \
                        else (m_off.sum() if not kick_on
                              else m_off.sum() + k_off.sum())
                    payload, scales_row, f_off = jax.lax.cond(
                        need > 0, _exchange, _dead)
                    executed = (need > 0).astype(jnp.float32)
                else:
                    payload, scales_row, f_off = _exchange()
                    executed = jnp.ones((), jnp.float32)
                if self.node_ring_on:
                    consumed = m_off + k_off if kick_on else m_off
                    rx = rx + executed * (consumed > 0).astype(jnp.float32)
                if kick_on:
                    kick_rows.append(k_off)
                # the traced gate flows into the edge weights: a masked
                # edge costs zero math in the fused kernel
                e_sym = 0.5 * (eta[idx, jidx] + eta[jidx, idx]) * m_off
                act = act + m_off
                w_rows.append(m_off)
            else:
                payload, scales_row, f_off = _exchange()
                if self.node_ring_on:
                    rx = rx + 1.0
                e_sym = 0.5 * (eta[idx, jidx] + eta[jidx, idx])    # [J]
            # scatter-free write of F[i, (i+off)%j]: static circulant mask
            # (an .at[].set scatter costs extra collective-permutes on SPMD)
            mask = jnp.asarray(np.roll(np.eye(j), off, axis=1), jnp.float32)
            f_nbr = f_nbr + f_off[:, None] * mask
            sym_sum = sym_sum + e_sym
            payloads.append(payload)
            scale_rows.append(scales_row)
            e_rows.append(e_sym)
            if pipelined and d + depth < deg:
                # bounded window: the next issue waits (only) on this
                # offset's consume token
                inflight[d + depth] = _issue_row(d + depth, token=f_off)

        wires = self._constrain_flat(jnp.stack(payloads))  # [deg, J, total]
        scales = jnp.stack(scale_rows)              # [deg, J, L]
        e_stack = jnp.stack(e_rows)                 # [deg, J]

        # -- fused round: dequant + means + prox + dual + residuals --------
        alpha = self.ccfg.prox_step / (1.0 + 2.0 * sym_sum)    # [J]
        if dynamic:
            # active-degree neighbor mean; ghosts (degree 0) get bar = 0
            inv_deg = jnp.where(act > 0, 1.0 / jnp.maximum(act, 1.0), 0.0)
            eta_node = sym_sum * inv_deg
            bar_w = jnp.stack(w_rows)               # [deg, J]
        else:
            eta_node = sym_sum / deg
            bar_w = inv_deg = None
        kick_w = jnp.stack(kick_rows) if kick_on else None
        if self.ccfg.use_fused_kernel:
            theta_new, lam_new, bar_new, r_sq, s_sq = self._fused_round(
                theta_flat, state.lam, state.theta_bar_prev, wires, scales,
                e_stack, alpha, sym_sum, eta_node,
                bar_w=bar_w, inv_deg=inv_deg, kick_w=kick_w)
        else:
            theta_new, lam_new, bar_new, r_sq, s_sq = \
                kref.consensus_round_ref(
                    theta_flat, state.lam, state.theta_bar_prev, wires,
                    scales, e_stack, alpha, sym_sum, eta_node,
                    block_leaf=lay.block_leaf, block_size=lay.block_size,
                    bar_w=bar_w, inv_deg=inv_deg, kick_w=kick_w,
                    scales_per_block=self.dequant_spec.per_block)

        params_new = lay.unpack(theta_new)
        r_norm = jnp.sqrt(r_sq)
        s_norm = jnp.sqrt(s_sq)

        if dynamic:
            # penalties keep adapting on gated GRAPH edges (the eq. 10
            # top-up must still see them to revive) and on repair edges,
            # but never on ghost rows/cols
            alive = topo.node_alive
            adj_pen = (adj & alive[:, None] & alive[None, :]) | topo.mask
        else:
            adj_pen = adj
        with self._span("consensus/penalty"):
            penalty_new = update_penalty(
                pcfg, state.penalty, adj=adj_pen, f_self=f_self,
                f_nbr=f_nbr, r_norm=r_norm, s_norm=s_norm)
            topo_new = self.topo_rt.update(
                topo, penalty=penalty_new,
                r_norm=r_norm) if dynamic else topo
        if kick_on:
            # edges the scheduler just gated: park their final consensus
            # force (the symmetrized weight applied THIS round) for the
            # kernel to absorb into the dual next round
            newly_off = (topo.mask & ~topo_new.mask).astype(jnp.float32)
            topo_new = topo_new._replace(
                kick=0.5 * (eta + eta.T) * newly_off)
        new = state._replace(params=params_new, lam=lam_new,
                             theta_bar_prev=bar_new, penalty=penalty_new,
                             topo=topo_new)
        if pipelined and self.async_cfg is not None:
            # the issued raw rows ARE next round's double buffer; w_prev
            # records the weights applied this round so an interleaved
            # bounded-staleness step absorbs kicks correctly. The PURE-sync
            # path skips this writeback: nothing consumes it — the async
            # invariant makes the first read of every edge fresh (the
            # zero-initialized ledger is never decoded), and the dead-offset
            # hold only needs a shape-stable row — so skipping saves a
            # wire-sized [deg, J, W] copy per round.
            new = new._replace(ledger=WireLedger(
                wires=self._constrain_flat(jnp.stack(inflight)),
                round=state.ledger.round + 1,
                w_prev=0.5 * (eta + eta.T) * (mask_f if dynamic else 1.0)))
        if dynamic:
            # ghost and zero-active-degree rows have bar = 0, so their
            # "residual" is the full parameter norm; an isolated node has
            # no consensus constraint — exclude both from the extremes
            alive_f = topo.node_alive.astype(jnp.float32) \
                * (act > 0).astype(jnp.float32)
            r_rep, s_rep = r_norm * alive_f, s_norm * alive_f
            f_rep = (f_self * alive_f).sum() / jnp.maximum(alive_f.sum(), 1)
        else:
            r_rep, s_rep, f_rep = r_norm, s_norm, f_self.mean()
        metrics = {
            "r_max": r_rep.max(), "s_max": s_rep.max(),
            "f_mean": f_rep,
            "eta_mean": jnp.where(adj, penalty_new.eta, 0.0).sum()
            / jnp.maximum(adj.sum(), 1),
            "active_edges": (active_edge_fraction(topo, adj) if dynamic
                             else jnp.ones(())),
        }
        node_metrics = None
        if self.node_ring_on:
            node_metrics = {
                "r": r_rep, "s": s_rep, "f_local": f_self,
                "eta_row_mean":
                    jnp.where(adj, penalty_new.eta, 0.0).sum(axis=1)
                    / jnp.maximum(adj.sum(axis=1), 1),
                "alive": (topo.node_alive.astype(jnp.float32) if dynamic
                          else jnp.ones((j,), jnp.float32)),
                "wire_rx_bytes": rx * float(self.codec.wire_bytes()),
            }
        return self._finish_round(new, metrics, node_metrics)

    # ------------------------------------------- async consensus round ----
    def consensus_step_async(self, state: TrainState, probe_batch: Any,
                             arrivals: jax.Array,
                             advance: jax.Array | None = None
                             ) -> tuple[TrainState, dict]:
        """One bounded-staleness consensus round (``repro.async_exec``).

        The synchronous round blocks on every graph offset before any
        node's prox/dual work runs. This variant instead consumes, per
        directed edge, the freshest payload that has LANDED — falling back
        to the double-buffered wire ledger (the payload consumed last
        round) when a neighbor is late — and treats a payload older than
        ``AsyncConfig.max_staleness`` rounds as a temporarily gated edge:
        zero math through the masked kernel, with the edge's final
        consensus force zero-kick-absorbed into the dual so gating
        preserves stationarity. A fresh arrival revives the edge the same
        round.

        Args:
          arrivals: [deg, J] bool, replicated — ``arrivals[d, i]`` means
            the payload from node ``(i + off_d) % J`` reached node i before
            this round's compute deadline (the host executor derives it
            from its round clock; in a real deployment it is the DMA
            completion bit of the double buffer).
          advance: optional [J] bool — nodes actually running a consensus
            round this fleet tick. A frozen (mid-compute) node keeps its
            params / duals / penalty rows; its staleness clocks still tick.

        With ``max_staleness=0`` no staleness is tolerated — the executor
        waits for every wire and this method IS the synchronous round
        (pinned bit-identical by test), with the ledger passing through
        untouched.
        """
        if self.async_cfg is None:
            raise ValueError("consensus_step_async needs ConsensusConfig."
                             "async_exec=AsyncConfig(...)")
        if self.num_nodes <= 1:
            return self._finish_round(state, {
                "r_max": jnp.zeros(()),
                "eta_mean": jnp.asarray(self.ccfg.penalty.eta0)})
        acfg = self.async_cfg
        if acfg.max_staleness == 0:
            # the sync round already emits the full unified key set (the
            # schema registry replaced this path's ad-hoc zero padding)
            return self.consensus_step(state, probe_batch)

        assert state.ledger is not None, "init_state builds the wire ledger"
        j = self.num_nodes
        offsets = self.offsets
        adj = jnp.asarray(self.graph.adj)
        pcfg = self.ccfg.penalty
        idx = jnp.arange(j)
        lay = self.layout
        dynamic = self.dynamic
        ledger: WireLedger = state.ledger
        vloss = self._probe_vloss()
        n_stale = acfg.max_staleness

        # ---- staleness clocks: tick, then gate -------------------------
        # arrivals [deg, J] -> the [J, J] clock grid via the static
        # circulant masks (scatter-free, mirroring the f_nbr writes)
        fresh = jnp.zeros((j, j), bool)
        covered = np.zeros((j, j), bool)
        for d, off in enumerate(offsets):
            circ = np.roll(np.eye(j, dtype=bool), off, axis=1)
            covered |= circ
            fresh = fresh | (arrivals[d][:, None] & jnp.asarray(circ))
        # pairs outside the compiled offset superset never move a payload;
        # keep their clocks at zero instead of counting phantom staleness
        fresh = fresh | jnp.asarray(~covered)
        prev_live = sym_age(state.topo) <= n_stale          # pre-tick view
        topo = tick_age(state.topo, fresh)
        age_s = sym_age(topo)
        live = age_s <= n_stale              # the bounded-staleness gate
        if self.topo_cfg.scheduler == "stale":
            # the mask's only gating source is staleness itself, which
            # `live` already recomputes from THIS round's clocks — gate on
            # the composed full-graph mask instead of last epoch's mask,
            # so a fresh arrival revives the edge the SAME round
            base_mask = compose_mask(adj, topo, adj)
            prev_base = compose_mask(adj, state.topo, adj)
        else:
            base_mask = prev_base = topo.mask
        gate_m = base_mask & live
        gate_f = gate_m.astype(jnp.float32)
        # the staleness-damped per-edge penalties actually applied this
        # round: eta / (1 + gamma * age) on active edges, zero on gated
        # ones, symmetrized so the dual weights stay symmetric. ONE source
        # of truth for the damping schedule: core.penalty.effective_eta.
        eta_eff = effective_eta(pcfg, state.penalty, gate_m, age=age_s,
                                stale_gamma=acfg.stale_gamma)
        w_applied = 0.5 * (eta_eff + eta_eff.T)            # [J, J]

        # ---- zero-kick bookkeeping -------------------------------------
        # (a) edges that just aged past the bound absorb THIS round from
        #     the ledger (their payload is exactly the last-known neighbor
        #     estimate the dual was built against), at EXACTLY the weight
        #     they applied last round (ledger.w_prev — the penalty state
        #     has advanced one update since, so it cannot be recomputed);
        # (b) edges the scheduler gated last round ride in topo.kick.
        newly_stale = prev_base & prev_live & ~live
        kick_m = jnp.where(newly_stale, ledger.w_prev, 0.0) + topo.kick

        with self._span("consensus/probe"):
            f_self = vloss(state.params, probe_batch)           # [J]
        with self._span("consensus/pack"):
            theta_flat = self._constrain_flat(
                lay.pack(state.params, dtype=lay.wire_dtype))
            wire = self._encode_wire(theta_flat)

        ones = jnp.ones((j, self.dequant_spec.scale_width), jnp.float32)
        sym_sum = jnp.zeros((j,), jnp.float32)
        act = jnp.zeros((j,), jnp.float32)
        f_nbr = jnp.zeros((j, j), jnp.float32)
        payloads, scale_rows, e_rows = [], [], []
        w_rows, kick_rows, ledger_rows = [], [], []
        # pipelined (pipeline_offsets >= 2): issue the offset permutes —
        # and their arrival merges against the held ledger rows — ahead of
        # the decode/probe consume loop, exactly like the sync round's
        # issue phase. Same bounded window via consume-token barriers;
        # bit-identical values at every depth.
        pipelined = self.pipelined
        depth = min(self.pipeline_depth, len(offsets)) if pipelined else 1
        landed: list = [None] * len(offsets)

        def _merge_row(d, token=None):
            off_d = offsets[d]
            arr_d = arrivals[d].astype(bool)                    # [J]
            held_d = ledger.wires[d]                            # [J, W]
            src = wire
            if token is not None:
                src, _ = jax.lax.optimization_barrier((src, token))

            def _issue(src=src, off_d=off_d):
                # round k's permute issues regardless of who consumes it
                # fresh — the overlap the executor's clock accounts for.
                # The barrier pins the wire dtype (see consensus_step).
                with self._span(f"consensus/exchange/off{off_d}"):
                    return jax.lax.optimization_barrier(
                        jnp.roll(src, -off_d, axis=0))

            def _hold(held_d=held_d):
                return held_d

            # nothing arrived on this offset => the in-flight payload is
            # still on the wire; skip the permute entirely this tick
            rolled = jax.lax.cond(arr_d.any(), _issue, _hold)
            return jnp.where(arr_d[:, None], rolled, held_d)

        for d0 in range(depth if pipelined else 0):
            landed[d0] = _merge_row(d0)

        for d, off in enumerate(offsets):
            jidx = (idx + off) % j
            merged = landed[d] if pipelined else _merge_row(d)
            payload, scales_row = self._decode_wire(merged)
            g_off = gate_f[idx, jidx]
            k_off = kick_m[idx, jidx]

            def _probe(payload=payload, scales_row=scales_row):
                with self._span("consensus/probe"):
                    return vloss(self._probe_params(payload, scales_row),
                                 probe_batch)

            # probe the payload actually consumed (stale ones included —
            # it IS our current estimate of the neighbor); a fully gated,
            # kick-free offset skips the forward pass
            f_off = jax.lax.cond((g_off.sum() + k_off.sum()) > 0,
                                 _probe, lambda: f_self)
            # staleness-damped symmetrized penalty: stale duals pull less
            e_sym = w_applied[idx, jidx]
            circ_f = jnp.asarray(np.roll(np.eye(j), off, axis=1),
                                 jnp.float32)
            f_nbr = f_nbr + f_off[:, None] * circ_f
            sym_sum = sym_sum + e_sym
            act = act + g_off
            payloads.append(payload)
            scale_rows.append(ones if scales_row is None else scales_row)
            e_rows.append(e_sym)
            w_rows.append(g_off)
            kick_rows.append(k_off)
            ledger_rows.append(merged)
            if pipelined and d + depth < len(offsets):
                landed[d + depth] = _merge_row(d + depth, token=f_off)

        wires = self._constrain_flat(jnp.stack(payloads))  # [deg, J, total]
        scales = jnp.stack(scale_rows)              # [deg, J, L]
        e_stack = jnp.stack(e_rows)                 # [deg, J]
        bar_w = jnp.stack(w_rows)
        kick_w = jnp.stack(kick_rows)

        alpha = self.ccfg.prox_step / (1.0 + 2.0 * sym_sum)
        inv_deg = jnp.where(act > 0, 1.0 / jnp.maximum(act, 1.0), 0.0)
        eta_node = sym_sum * inv_deg
        if self.ccfg.use_fused_kernel:
            theta_new, lam_new, bar_new, r_sq, s_sq = self._fused_round(
                theta_flat, state.lam, state.theta_bar_prev, wires, scales,
                e_stack, alpha, sym_sum, eta_node,
                bar_w=bar_w, inv_deg=inv_deg, kick_w=kick_w)
        else:
            theta_new, lam_new, bar_new, r_sq, s_sq = \
                kref.consensus_round_ref(
                    theta_flat, state.lam, state.theta_bar_prev, wires,
                    scales, e_stack, alpha, sym_sum, eta_node,
                    block_leaf=lay.block_leaf, block_size=lay.block_size,
                    bar_w=bar_w, inv_deg=inv_deg, kick_w=kick_w,
                    scales_per_block=self.dequant_spec.per_block)

        params_new = lay.unpack(theta_new)
        r_norm = jnp.sqrt(r_sq)
        s_norm = jnp.sqrt(s_sq)

        # penalties keep adapting on stale-gated and scheduler-gated graph
        # edges (the eq. 10 top-up revives them) but never on ghost rows
        alive = topo.node_alive
        adj_pen = (adj & alive[:, None] & alive[None, :]) | topo.mask
        with self._span("consensus/penalty"):
            penalty_new = update_penalty(
                pcfg, state.penalty, adj=adj_pen, f_self=f_self,
                f_nbr=f_nbr, r_norm=r_norm, s_norm=s_norm)
            topo_new = self.topo_rt.update(
                topo, penalty=penalty_new,
                r_norm=r_norm) if dynamic else topo
        if dynamic and self.topo_cfg.can_gate:
            # park kicks ONLY for edges that were ACTIVE this round (mask
            # AND within the staleness bound): an edge that aged out was
            # already absorbed in-round — the scheduler mirroring it out
            # of the mask one epoch later must not absorb it twice
            kick_next = w_applied \
                * (gate_m & ~topo_new.mask).astype(jnp.float32)
        else:
            kick_next = jnp.zeros_like(topo.kick)
        topo_new = topo_new._replace(kick=kick_next)
        ledger_new = WireLedger(wires=self._constrain_flat(
            jnp.stack(ledger_rows)),
            round=ledger.round + 1, w_prev=w_applied)

        new = state._replace(params=params_new, lam=lam_new,
                             theta_bar_prev=bar_new, penalty=penalty_new,
                             topo=topo_new, ledger=ledger_new)
        if advance is not None:
            new = self._freeze_rows(advance, new, state,
                                    topo_new=topo_new,
                                    ledger_new=ledger_new)

        alive_f = topo.node_alive.astype(jnp.float32) \
            * (act > 0).astype(jnp.float32)
        if advance is not None:
            # frozen nodes ran no real round: their residual rows were
            # discarded by _freeze_rows, so keep them out of the extremes
            alive_f = alive_f * advance.astype(jnp.float32)
        r_rep, s_rep = r_norm * alive_f, s_norm * alive_f
        f_rep = (f_self * alive_f).sum() / jnp.maximum(alive_f.sum(), 1)
        mask_edges = jnp.maximum(base_mask.astype(jnp.float32).sum(), 1.0)
        metrics = {
            "r_max": r_rep.max(), "s_max": s_rep.max(),
            "f_mean": f_rep,
            "eta_mean": jnp.where(adj, penalty_new.eta, 0.0).sum()
            / jnp.maximum(adj.sum(), 1),
            "active_edges": (active_edge_fraction(topo, adj) if dynamic
                             else jnp.ones(())),
            "stale_edges": (base_mask & ~live).astype(jnp.float32).sum()
            / mask_edges,
            "age_max": jnp.where(base_mask, age_s, 0).max(),
        }
        node_metrics = None
        if self.node_ring_on:
            # fresh wire bytes per node: offsets whose arrival bit was set
            # for this node this tick (held ledger payloads are not re-paid)
            rx = sum(arrivals[d].astype(jnp.float32)
                     for d in range(len(offsets)))
            node_metrics = {
                "r": r_rep, "s": s_rep, "f_local": f_self,
                "eta_row_mean":
                    jnp.where(adj, penalty_new.eta, 0.0).sum(axis=1)
                    / jnp.maximum(adj.sum(axis=1), 1),
                "age_max": jnp.where(base_mask, age_s, 0).max(axis=1),
                "alive": topo.node_alive.astype(jnp.float32),
                "advance": (advance.astype(jnp.float32)
                            if advance is not None
                            else jnp.ones((j,), jnp.float32)),
                "wire_rx_bytes": rx * float(self.codec.wire_bytes()),
            }
        return self._finish_round(new, metrics, node_metrics)

    def _freeze_rows(self, advance: jax.Array, new: TrainState,
                     old: TrainState, *, topo_new, ledger_new) -> TrainState:
        """Keep non-advancing nodes' state from ``old`` (async fleet tick).

        A node mid-compute at the tick deadline runs no prox/dual update:
        its params, duals and neighbor mean rows stay put. The PENALTY
        freezes per EDGE instead (``core.penalty.freeze_penalty``): an edge
        whose other endpoint advanced keeps adapting in BOTH directions, so
        a frozen node's incident columns and rows stay symmetric — the old
        whole-row freeze let eta[j, i] run ahead of a frozen eta[i, j].
        Staleness clocks and the shared topology/ledger state always
        advance — they model the network, not the node's compute.
        """
        adv = advance.astype(bool)

        def rows(a, b):
            sel = adv.reshape((adv.shape[0],) + (1,) * (a.ndim - 1))
            return jnp.where(sel, a, b)

        penalty = freeze_penalty(advance, new.penalty, old.penalty)
        return new._replace(
            params=jax.tree_util.tree_map(rows, new.params, old.params),
            lam=rows(new.lam, old.lam),
            theta_bar_prev=rows(new.theta_bar_prev, old.theta_bar_prev),
            penalty=penalty, topo=topo_new, ledger=ledger_new)

    # ------------------------------------------------------------- churn ----
    def apply_churn(self, state: TrainState, victim: int) -> TrainState:
        """Host-side layout-preserving node drop — a topology epoch, not a
        crash, and NOT a recompilation: the [J, ...] shapes are unchanged,
        only ``state.topo`` (liveness, mask, repair edges) is rewritten.

        The compiled step functions keep executing; the victim becomes a
        ghost row whose edges all cost zero math. Requires a dynamic
        topology config (``churn=True`` or a non-static scheduler) so the
        engine compiled the masked kernel and the repair offset superset.
        """
        if not self.dynamic:
            raise ValueError(
                "node churn needs ConsensusConfig.dyn_topology with "
                "churn=True (or a non-static scheduler)")
        # drop_node preserves the old leaves' committed shardings, so the
        # jitted step functions keep their cache
        return state._replace(topo=self.topo_rt.drop_node(state.topo,
                                                          victim))

    # ------------------------------------------------------------ driver ----
    def jit_step_fns(self):
        """Jitted (train_step, consensus_step) with the state DONATED.

        Donation lets XLA reuse the state buffers for the outputs — combined
        with the kernel's input/output aliasing the flat theta/lam/bar
        buffers are updated in place, not copied once per round.
        """
        return (jax.jit(self.train_step, donate_argnums=(0,)),
                jax.jit(self.consensus_step, donate_argnums=(0,)))

    def jit_async_step_fns(self):
        """Jitted consensus_step_async with the state donated.

        Deliberately does NOT hand out a donated train_step: the local
        step is the one that gets wrapped in ``with_retries`` (which may
        replay the same state buffers) — callers jit it undonated
        themselves, exactly like the sync launcher does.
        """
        return jax.jit(self.consensus_step_async, donate_argnums=(0,))

    def should_sync(self, step: int) -> bool:
        return self.num_nodes > 1 and (step + 1) % self.ccfg.local_steps == 0
