"""Flat-buffer packing for the consensus engine — one HBM pass per round.

The consensus round is pure elementwise math over every parameter, so its
natural data layout is not a pytree but one contiguous vector per node.
``FlatLayout`` computes a *static* layout table for a parameter pytree —
element offset / true size / padded size / shape / dtype per leaf — and packs
the per-node state (params, duals, neighbor means) into a single
``[J, total]`` buffer. Everything downstream gets simpler and faster:

  * the neighbor exchange is ONE collective-permute per graph offset over
    contiguous bytes (instead of one per leaf),
  * the fused Pallas kernel (``repro.kernels.consensus_update
    .consensus_round``) runs once over the whole vector,
  * compressed wire scales ride *inside* the same buffer (bitcast to int8
    and appended as a tail) so quantized exchange still needs one permute.

The wire FORMAT itself lives in ``repro.wire`` (the pluggable codec
subsystem: native / int8 / fp8 per-block, see ``docs/wire_formats.md``);
the ``encode_int8`` / ``decode_split`` / ``wire_bytes`` methods here are
kept as thin delegates into the ``int8`` codec for compatibility.

Layout invariants:

  * every leaf is padded to a multiple of ``block_size`` and starts
    block-aligned, so each kernel block maps to exactly ONE leaf — the
    per-block dequantization scale is a scalar-prefetch lookup
    ``scales[leaf_of_block[b]]``;
  * padding is zero-filled by ``pack`` and kept zero by the round math
    (theta = lam = nbr = bar = 0 on padding => all updates and both residual
    reductions contribute exactly 0), which is what makes the padded
    reductions equal the masked ones.

Sharding (``FlatLayout.shard`` -> ``ShardedLayout``): the flat axis splits
on block boundaries into ``n_shards`` equal slabs, one per in-pod device,
so per-device HBM for the consensus state scales down with the in-pod mesh.
Each shard gets its own slab of the block->leaf table (global leaf ids, so
the replicated per-leaf scales index directly) and the int8 wire carries a
bitcast f32 scale tail PER SHARD — every device encodes/decodes its slab
with only local bytes, and the neighbor permute still moves one contiguous
buffer per offset.

All tables are static numpy / Python ints — only buffer contents are traced.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def auto_block_size(tree: Any, *, lo: int = 128, hi: int = 65536) -> int:
    """Pick a layout block size for a per-node parameter tree.

    The per-leaf alignment wastes < block_size elements per leaf, so the
    block should track the mean leaf size: LM-scale leaves (>= 64k elements)
    get the full 64k Pallas block, tiny debug models get small blocks and
    negligible padding. Power of two, clamped to [lo, hi].
    """
    sizes = [int(np.prod(x.shape, dtype=np.int64)) or 1
             for x in jax.tree_util.tree_leaves(tree)]
    if not sizes:
        return lo
    mean = sum(sizes) / len(sizes)
    bs = lo
    while bs < hi and bs < mean:
        bs *= 2
    return bs


class LeafSpec(NamedTuple):
    offset: int                 # element offset into the flat axis (aligned)
    size: int                   # true elements per node
    padded: int                 # size rounded up to the block multiple
    shape: tuple[int, ...]      # per-node shape (leading node axis removed)
    dtype: Any                  # original leaf dtype


class FlatLayout:
    """Static layout table mapping a pytree to one flat [J, total] buffer."""

    def __init__(self, treedef, leaves: tuple[LeafSpec, ...],
                 block_size: int):
        self.treedef = treedef
        self.leaves = leaves
        self.block_size = int(block_size)
        self.total = (leaves[-1].offset + leaves[-1].padded) if leaves else 0
        assert self.total % self.block_size == 0, (self.total, block_size)
        self.num_blocks = self.total // self.block_size
        self.num_leaves = len(leaves)
        block_leaf = np.zeros((self.num_blocks,), np.int32)
        for li, lf in enumerate(leaves):
            block_leaf[lf.offset // self.block_size:
                       (lf.offset + lf.padded) // self.block_size] = li
        self.block_leaf = block_leaf          # [num_blocks] leaf id per block

    # ---------------------------------------------------------- factory ----
    @classmethod
    def for_tree(cls, tree: Any, *, block_size: int = 65536,
                 node_axis: bool = True, shards: int = 1) -> "FlatLayout":
        """Build the table from arrays or ShapeDtypeStructs.

        ``node_axis=True`` treats leaves as ``[J, ...]`` stacks and lays out
        the per-node tail shape (the trainer's case).

        ``shards > 1`` additionally aligns the TOTAL to a multiple of
        ``shards * block_size`` (extra zero padding folded into the last
        leaf's padded span) so ``shard(shards)`` splits the flat axis into
        equal block-aligned slabs. ``shards=1`` is byte-identical to the
        unsharded layout.
        """
        arrs, treedef = jax.tree_util.tree_flatten(tree)
        specs: list[LeafSpec] = []
        off = 0
        bs = int(block_size)
        for x in arrs:
            shape = tuple(x.shape[1:] if node_axis else x.shape)
            size = int(np.prod(shape, dtype=np.int64)) if shape else 1
            padded = -(-size // bs) * bs
            specs.append(LeafSpec(off, size, padded, shape,
                                  jnp.dtype(x.dtype)))
            off += padded
        if shards > 1 and specs:
            align = bs * int(shards)
            total = -(-off // align) * align
            if total != off:
                last = specs[-1]
                specs[-1] = last._replace(padded=last.padded + total - off)
        return cls(treedef, tuple(specs), bs)

    @property
    def waste_frac(self) -> float:
        """Fraction of the flat buffer that is alignment padding."""
        true = sum(lf.size for lf in self.leaves)
        return 1.0 - true / self.total if self.total else 0.0

    @property
    def wire_dtype(self):
        """Dtype of the uncompressed wire buffer: the leaves' common float
        type (bf16 params -> bf16 wire, matching the pre-flat per-leaf
        exchange; any f32 leaf promotes the whole buffer)."""
        if not self.leaves:
            return jnp.float32
        return jnp.result_type(*[lf.dtype for lf in self.leaves])

    def wire_bytes(self, compression: str) -> int:
        """Bytes per node moved by ONE graph-offset permute of the wire.

        ``compression`` is any codec name (``repro.wire.WIRE_CODECS``) or
        the legacy ``"none"`` spelling. Delegates to the codec — the
        single source of truth for wire accounting (the dry-run roofline
        and the benchmarks both read this).
        """
        from repro import wire
        return wire.get_codec(compression, self).wire_bytes()

    # ------------------------------------------------------- pack/unpack ----
    def pack(self, tree: Any, dtype=jnp.float32) -> jax.Array:
        """Pytree of [J, ...] leaves -> [J, total] buffer (zero padding)."""
        arrs = self.treedef.flatten_up_to(tree)
        j = arrs[0].shape[0]
        parts = []
        for lf, x in zip(self.leaves, arrs):
            flat = x.astype(dtype).reshape(j, lf.size)
            if lf.padded > lf.size:
                flat = jnp.pad(flat, ((0, 0), (0, lf.padded - lf.size)))
            parts.append(flat)
        return jnp.concatenate(parts, axis=1)

    def unpack(self, buf: jax.Array, *, scales: jax.Array | None = None,
               scales_per_block: bool = False) -> Any:
        """[J, total] buffer -> pytree of [J, ...] leaves in leaf dtype.

        ``scales`` (optional) dequantizes a quantized payload: per-leaf
        ``[J, num_leaves]`` rows by default (leaf li is multiplied by
        ``scales[:, li]``), or — with ``scales_per_block`` — per-block
        ``[J, num_blocks]`` rows on the layout's block grid (the fp8
        codecs). The slice/scale/reshape chain is elementwise per leaf,
        so XLA fuses it into the consumer — no standalone full-size
        materialization pass.
        """
        j = buf.shape[0]
        out = []
        if scales is not None and scales_per_block:
            sv = jnp.repeat(scales, self.block_size, axis=-1,
                            total_repeat_length=self.total)
        for li, lf in enumerate(self.leaves):
            seg = buf[:, lf.offset:lf.offset + lf.size]
            if scales is not None:
                seg = seg.astype(jnp.float32) * (
                    sv[:, lf.offset:lf.offset + lf.size] if scales_per_block
                    else scales[:, li:li + 1])
            out.append(seg.reshape((j,) + lf.shape).astype(lf.dtype))
        return jax.tree_util.tree_unflatten(self.treedef, out)

    # -------------------------------------------------------- wire codec ----
    def leaf_scales(self, buf: jax.Array) -> jax.Array:
        """Per-node, per-leaf int8 absmax scales [J, num_leaves] (f32)."""
        cols = []
        for lf in self.leaves:
            seg = buf[:, lf.offset:lf.offset + lf.size]
            # initial=0.0 is a no-op for non-empty leaves (|x| >= 0) and
            # keeps empty leaves (size 0) from reducing over nothing
            amax = jnp.abs(seg.astype(jnp.float32)).max(axis=1, initial=0.0)
            cols.append(jnp.maximum(amax, 1e-12) / 127.0)
        return jnp.stack(cols, axis=1).astype(jnp.float32)

    def block_scales(self, scales: jax.Array) -> jax.Array:
        """Expand per-leaf scales [..., num_leaves] -> per-block
        [..., num_blocks] via the static block->leaf table."""
        return scales[..., self.block_leaf]

    def scale_vector(self, scales: jax.Array) -> jax.Array:
        """Per-leaf scales [..., num_leaves] -> full-width [..., total]."""
        return jnp.repeat(self.block_scales(scales), self.block_size,
                          axis=-1, total_repeat_length=self.total)

    def encode_int8(self, buf: jax.Array) -> jax.Array:
        """f32 [J, total] -> int8 wire [J, total + 4*num_leaves].

        Thin delegate into the ``int8`` wire codec (``repro.wire`` — the
        format moved there verbatim): absmax-quantized per (node, leaf),
        f32 scales bitcast to int8 and appended, so the whole message is
        ONE contiguous int8 buffer.
        """
        from repro import wire
        return wire.get_codec("int8", self).encode(buf)

    def decode_split(self, wire: jax.Array
                     ) -> tuple[jax.Array, jax.Array | None]:
        """int8 wire -> (payload [J, total] int8, scales [J, L] f32).

        For an uncompressed (float) wire returns (wire, None). Delegates
        into the ``int8`` wire codec.
        """
        from repro import wire as wire_lib
        return wire_lib.get_codec("int8", self).decode(wire)

    # ----------------------------------------------------------- shard ----
    def shard(self, n_shards: int) -> "ShardedLayout":
        """Split the flat axis on block boundaries into ``n_shards`` equal
        slabs (per-shard layout tables). Build the layout with
        ``for_tree(..., shards=n_shards)`` so the block count divides."""
        return ShardedLayout(self, n_shards)


class ShardSpec(NamedTuple):
    """Static layout table for ONE slab of the flat axis."""

    index: int                  # shard id (= device position on in-pod axes)
    start: int                  # element offset of the slab in the flat axis
    size: int                   # elements in the slab (uniform across shards)
    block_leaf: np.ndarray      # [blocks_per_shard] GLOBAL leaf id per block
    leaf_lo: int                # first leaf id overlapping the slab
    leaf_hi: int                # last leaf id overlapping the slab (incl.)


class ShardedLayout:
    """Per-shard view of a ``FlatLayout`` for in-pod sharded buffers.

    The flat ``[J, total]`` buffers shard as ``P('pod', <in-pod axes>)``:
    device s of a pod holds slab ``[start_s : start_s + shard_total]`` of
    its node's row. Because slab boundaries are block boundaries, each
    shard owns whole blocks and its slice of the block->leaf table is a
    valid layout table on its own (global leaf ids, so the replicated
    ``[.., num_leaves]`` scale rows index it directly).

    Sharded int8 wire format (``encode_int8`` / ``split_wire``): each
    shard's message is ``[q(slab), bitcast(local scales)]`` — the tail
    carries ONLY the scales of the leaves overlapping that slab
    (``tail_gather`` below; 4*tail_leaves bytes), so the per-node wire
    pays the scale bytes ~once, not once per shard, matching the fp8
    codec's split-with-the-slabs discipline. Every per-device slab stays
    SELF-CONTAINED: the bytes a device holds (or keeps in its wire-ledger
    row) are sufficient to dequantize its slab — what a per-device
    decoder / RDMA mailbox needs on real hardware. The whole per-node
    wire stays one contiguous ``[J, n_shards * shard_wire_width]`` buffer
    moved by one collective-permute per graph offset. (In the GSPMD
    simulation the replicated ``[J, L]`` scale row the kernel and probes
    consume is reassembled from the per-shard tails via the static
    ``leaf_shard``/``leaf_pos`` tables — a ~4*L-byte in-pod gather per
    offset, noise next to the slab payloads.)

    Tail tables: per shard the local leaf window is the contiguous id
    range ``[tail_leaf_lo[s], tail_leaf_lo[s] + span_s)`` of leaves whose
    ``[offset, offset + padded)`` span touches the slab; zero-size leaves
    anchor to the shard containing their offset so every leaf appears in
    at least one tail and the full scale row reconstructs byte-exactly.
    ``tail_leaves`` is the max span (uniform per-shard width — the wire
    must reshape to ``[J, n_shards, w]``); shorter windows pad by
    repeating their last leaf id.
    """

    def __init__(self, layout: FlatLayout, n_shards: int):
        n_shards = int(n_shards)
        if n_shards < 1:
            raise ValueError(f"n_shards {n_shards} < 1")
        if layout.num_blocks % n_shards != 0:
            raise ValueError(
                f"{layout.num_blocks} blocks not divisible by {n_shards} "
                f"shards — build the layout with for_tree(..., shards=n)")
        self.layout = layout
        self.n_shards = n_shards
        bps = layout.num_blocks // n_shards
        self.blocks_per_shard = bps
        self.shard_total = bps * layout.block_size
        shards = []
        for s in range(n_shards):
            bl = layout.block_leaf[s * bps:(s + 1) * bps]
            shards.append(ShardSpec(
                index=s, start=s * self.shard_total, size=self.shard_total,
                block_leaf=bl,
                leaf_lo=int(bl[0]) if bl.size else 0,
                leaf_hi=int(bl[-1]) if bl.size else 0))
        self.shards = tuple(shards)
        # [n_shards, blocks_per_shard] — fed to the kernel as a TRACED
        # operand sharded over the in-pod axes (each device reads its row)
        self.block_leaf_shards = (
            np.stack([s.block_leaf for s in shards])
            if shards and bps else np.zeros((n_shards, bps), np.int32))
        self._build_tail_tables()

    def _build_tail_tables(self):
        """Static tables for the shard-local int8 scale tail (docstring)."""
        lay = self.layout
        n_leaves = lay.num_leaves
        total = self.n_shards * self.shard_total
        los, spans = [], []
        for s in range(self.n_shards):
            start, end = s * self.shard_total, (s + 1) * self.shard_total
            ids = [li for li, lf in enumerate(lay.leaves)
                   if (lf.padded > 0 and lf.offset < end
                       and lf.offset + lf.padded > start)
                   or (lf.padded == 0 and start <= lf.offset
                       and (lf.offset < end or end >= total))]
            los.append(min(ids) if ids else 0)
            spans.append(max(ids) - min(ids) + 1 if ids else 0)
        self.tail_leaf_lo = np.asarray(los, np.int32)       # [n_shards]
        self.tail_leaves = max(spans) if spans else 0       # uniform width
        # [n_shards, tail_leaves]: global leaf id at tail slot k of shard s
        # (windows shorter than the max pad by repeating their last leaf)
        if n_leaves and self.tail_leaves:
            self.tail_gather = np.stack([
                np.minimum(lo + np.arange(self.tail_leaves),
                           min(lo + span, n_leaves) - 1 if span else lo)
                for lo, span in zip(los, spans)]).astype(np.int32)
        else:
            self.tail_gather = np.zeros((self.n_shards, self.tail_leaves),
                                        np.int32)
        # [num_leaves]: where decode reads each leaf's scale back from —
        # the first shard whose window holds it (spanning leaves appear in
        # several tails with identical bytes; any copy reconstructs)
        leaf_shard = np.zeros(n_leaves, np.int32)
        leaf_pos = np.zeros(n_leaves, np.int32)
        for li in range(n_leaves):
            for s, (lo, span) in enumerate(zip(los, spans)):
                if span and lo <= li < lo + span:
                    leaf_shard[li], leaf_pos[li] = s, li - lo
                    break
            else:
                raise AssertionError(
                    f"leaf {li} missing from every shard tail window")
        self.leaf_shard, self.leaf_pos = leaf_shard, leaf_pos

    # ------------------------------------------------------- wire widths ----
    def wire_width(self, compression: str) -> int:
        """Elements in ONE shard's wire message (any codec name)."""
        from repro import wire
        codec = wire.get_codec(compression, self.layout, self)
        return codec.shard_wire_width

    def wire_row_bytes(self, compression: str) -> int:
        """Bytes of ONE shard's wire message — the per-device slab a
        permute moves and a ledger row holds. The single source of truth
        for per-device sharded wire accounting (mirrors
        ``FlatLayout.wire_bytes``'s role for the unsharded row)."""
        from repro import wire
        return wire.get_codec(compression, self.layout, self).wire_row_bytes()

    def wire_bytes(self, compression: str) -> int:
        """Bytes per node moved by ONE graph-offset permute (all shards).

        Both compressed tails split with the slabs — fp8 per-block scales
        exactly, int8 per-leaf scales shard-locally (each slab carries its
        own leaf window; only boundary-spanning leaves and the uniform
        ``tail_leaves`` padding duplicate) — so the sharded wire pays the
        scale bytes ~once per node, not once per shard.
        """
        from repro import wire
        return wire.get_codec(compression, self.layout, self).wire_bytes()

    # ------------------------------------------------------- wire codec ----
    def encode_int8(self, buf: jax.Array) -> jax.Array:
        """f32 [J, total] -> sharded int8 wire [J, n_shards * shard_w].

        Thin delegate into the ``int8`` wire codec (``repro.wire``): the
        quantized payload is IDENTICAL to ``FlatLayout.encode_int8`` —
        only the scale tail's placement differs (bitcast and replicated
        per shard, so every per-device slab is self-contained).
        """
        from repro import wire
        return wire.get_codec("int8", self.layout, self).encode(buf)

    def split_wire(self, wire: jax.Array
                   ) -> tuple[jax.Array, jax.Array | None]:
        """Sharded wire -> (payload [J, total], scales [J, L] | None).

        Delegates into the ``int8`` wire codec. For an uncompressed
        (float) wire — which carries no tails — returns ``(wire, None)``
        untouched, like ``FlatLayout.decode_split``.
        """
        from repro import wire as wire_lib
        return wire_lib.get_codec("int8", self.layout, self).decode(wire)
