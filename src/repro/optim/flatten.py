"""Flat-buffer packing for the consensus engine — one HBM pass per round.

The consensus round is pure elementwise math over every parameter, so its
natural data layout is not a pytree but one contiguous vector per node.
``FlatLayout`` computes a *static* layout table for a parameter pytree —
element offset / true size / padded size / shape / dtype per leaf — and packs
the per-node state (params, duals, neighbor means) into a single
``[J, total]`` buffer. Everything downstream gets simpler and faster:

  * the neighbor exchange is ONE collective-permute per graph offset over
    contiguous bytes (instead of one per leaf),
  * the fused Pallas kernel (``repro.kernels.consensus_update
    .consensus_round``) runs once over the whole vector,
  * int8 wire scales ride *inside* the same buffer (bitcast to int8 and
    appended as a tail) so quantized exchange still needs only one permute.

Layout invariants:

  * every leaf is padded to a multiple of ``block_size`` and starts
    block-aligned, so each kernel block maps to exactly ONE leaf — the
    per-block dequantization scale is a scalar-prefetch lookup
    ``scales[leaf_of_block[b]]``;
  * padding is zero-filled by ``pack`` and kept zero by the round math
    (theta = lam = nbr = bar = 0 on padding => all updates and both residual
    reductions contribute exactly 0), which is what makes the padded
    reductions equal the masked ones.

All tables are static numpy / Python ints — only buffer contents are traced.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def auto_block_size(tree: Any, *, lo: int = 128, hi: int = 65536) -> int:
    """Pick a layout block size for a per-node parameter tree.

    The per-leaf alignment wastes < block_size elements per leaf, so the
    block should track the mean leaf size: LM-scale leaves (>= 64k elements)
    get the full 64k Pallas block, tiny debug models get small blocks and
    negligible padding. Power of two, clamped to [lo, hi].
    """
    sizes = [int(np.prod(x.shape, dtype=np.int64)) or 1
             for x in jax.tree_util.tree_leaves(tree)]
    if not sizes:
        return lo
    mean = sum(sizes) / len(sizes)
    bs = lo
    while bs < hi and bs < mean:
        bs *= 2
    return bs


class LeafSpec(NamedTuple):
    offset: int                 # element offset into the flat axis (aligned)
    size: int                   # true elements per node
    padded: int                 # size rounded up to the block multiple
    shape: tuple[int, ...]      # per-node shape (leading node axis removed)
    dtype: Any                  # original leaf dtype


class FlatLayout:
    """Static layout table mapping a pytree to one flat [J, total] buffer."""

    def __init__(self, treedef, leaves: tuple[LeafSpec, ...],
                 block_size: int):
        self.treedef = treedef
        self.leaves = leaves
        self.block_size = int(block_size)
        self.total = (leaves[-1].offset + leaves[-1].padded) if leaves else 0
        assert self.total % self.block_size == 0, (self.total, block_size)
        self.num_blocks = self.total // self.block_size
        self.num_leaves = len(leaves)
        block_leaf = np.zeros((self.num_blocks,), np.int32)
        for li, lf in enumerate(leaves):
            block_leaf[lf.offset // self.block_size:
                       (lf.offset + lf.padded) // self.block_size] = li
        self.block_leaf = block_leaf          # [num_blocks] leaf id per block

    # ---------------------------------------------------------- factory ----
    @classmethod
    def for_tree(cls, tree: Any, *, block_size: int = 65536,
                 node_axis: bool = True) -> "FlatLayout":
        """Build the table from arrays or ShapeDtypeStructs.

        ``node_axis=True`` treats leaves as ``[J, ...]`` stacks and lays out
        the per-node tail shape (the trainer's case).
        """
        arrs, treedef = jax.tree_util.tree_flatten(tree)
        specs: list[LeafSpec] = []
        off = 0
        bs = int(block_size)
        for x in arrs:
            shape = tuple(x.shape[1:] if node_axis else x.shape)
            size = int(np.prod(shape, dtype=np.int64)) if shape else 1
            padded = -(-size // bs) * bs
            specs.append(LeafSpec(off, size, padded, shape,
                                  jnp.dtype(x.dtype)))
            off += padded
        return cls(treedef, tuple(specs), bs)

    @property
    def waste_frac(self) -> float:
        """Fraction of the flat buffer that is alignment padding."""
        true = sum(lf.size for lf in self.leaves)
        return 1.0 - true / self.total if self.total else 0.0

    @property
    def wire_dtype(self):
        """Dtype of the uncompressed wire buffer: the leaves' common float
        type (bf16 params -> bf16 wire, matching the pre-flat per-leaf
        exchange; any f32 leaf promotes the whole buffer)."""
        if not self.leaves:
            return jnp.float32
        return jnp.result_type(*[lf.dtype for lf in self.leaves])

    def wire_bytes(self, compression: str) -> int:
        """Bytes per node moved by ONE graph-offset permute of the wire.

        The single source of truth for wire accounting — the dry-run
        roofline and the benchmarks both read this.
        """
        if compression == "int8":
            return self.total + 4 * self.num_leaves   # payload + scale tail
        return self.total * jnp.dtype(self.wire_dtype).itemsize

    # ------------------------------------------------------- pack/unpack ----
    def pack(self, tree: Any, dtype=jnp.float32) -> jax.Array:
        """Pytree of [J, ...] leaves -> [J, total] buffer (zero padding)."""
        arrs = self.treedef.flatten_up_to(tree)
        j = arrs[0].shape[0]
        parts = []
        for lf, x in zip(self.leaves, arrs):
            flat = x.astype(dtype).reshape(j, lf.size)
            if lf.padded > lf.size:
                flat = jnp.pad(flat, ((0, 0), (0, lf.padded - lf.size)))
            parts.append(flat)
        return jnp.concatenate(parts, axis=1)

    def unpack(self, buf: jax.Array, *, scales: jax.Array | None = None
               ) -> Any:
        """[J, total] buffer -> pytree of [J, ...] leaves in leaf dtype.

        ``scales`` ([J, num_leaves], optional) dequantizes an int8 payload:
        leaf li is multiplied by ``scales[:, li]``. The slice/scale/reshape
        chain is elementwise per leaf, so XLA fuses it into the consumer —
        no standalone full-size materialization pass.
        """
        j = buf.shape[0]
        out = []
        for li, lf in enumerate(self.leaves):
            seg = buf[:, lf.offset:lf.offset + lf.size]
            if scales is not None:
                seg = seg.astype(jnp.float32) * scales[:, li:li + 1]
            out.append(seg.reshape((j,) + lf.shape).astype(lf.dtype))
        return jax.tree_util.tree_unflatten(self.treedef, out)

    # -------------------------------------------------------- wire codec ----
    def leaf_scales(self, buf: jax.Array) -> jax.Array:
        """Per-node, per-leaf int8 absmax scales [J, num_leaves] (f32)."""
        cols = []
        for lf in self.leaves:
            seg = buf[:, lf.offset:lf.offset + lf.size]
            amax = jnp.abs(seg.astype(jnp.float32)).max(axis=1)
            cols.append(jnp.maximum(amax, 1e-12) / 127.0)
        return jnp.stack(cols, axis=1).astype(jnp.float32)

    def block_scales(self, scales: jax.Array) -> jax.Array:
        """Expand per-leaf scales [..., num_leaves] -> per-block
        [..., num_blocks] via the static block->leaf table."""
        return scales[..., self.block_leaf]

    def scale_vector(self, scales: jax.Array) -> jax.Array:
        """Per-leaf scales [..., num_leaves] -> full-width [..., total]."""
        return jnp.repeat(self.block_scales(scales), self.block_size,
                          axis=-1, total_repeat_length=self.total)

    def encode_int8(self, buf: jax.Array) -> jax.Array:
        """f32 [J, total] -> int8 wire [J, total + 4*num_leaves].

        The payload is absmax-quantized per (node, leaf); the f32 scales are
        bitcast to int8 and appended, so the whole wire message is ONE
        contiguous int8 buffer — one collective-permute moves payload and
        scales together.
        """
        scales = self.leaf_scales(buf)                      # [J, L]
        q = jnp.clip(jnp.round(buf / self.scale_vector(scales)),
                     -127, 127).astype(jnp.int8)
        tail = jax.lax.bitcast_convert_type(scales, jnp.int8)  # [J, L, 4]
        return jnp.concatenate([q, tail.reshape(q.shape[0], -1)], axis=1)

    def decode_split(self, wire: jax.Array
                     ) -> tuple[jax.Array, jax.Array | None]:
        """int8 wire -> (payload [J, total] int8, scales [J, L] f32).

        For an uncompressed (float) wire returns (wire, None).
        """
        if wire.dtype != jnp.int8:
            return wire, None
        payload = wire[:, :self.total]
        tail = wire[:, self.total:].reshape(wire.shape[0],
                                            self.num_leaves, 4)
        scales = jax.lax.bitcast_convert_type(tail, jnp.float32)
        return payload, scales
