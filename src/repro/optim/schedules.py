"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int, total: int, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return warm * cos


def constant(step, *, value: float = 1.0):
    del step
    return value


def rsqrt(step, *, warmup: int):
    step = jnp.asarray(step, jnp.float32) + 1.0
    return jnp.minimum(step / warmup, jnp.sqrt(warmup / step))
