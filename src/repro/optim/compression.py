"""Gradient/parameter-delta compression for the consensus exchange.

Cross-pod (DCN) bandwidth is the scarce resource in multi-pod consensus
training. Two standard schemes, both with error feedback so the consensus
dual absorbs quantization error instead of accumulating bias:

  * int8  — per-tensor absmax scaling (8x reduction over f32, 2x over bf16)
  * topk  — magnitude top-k with error-feedback residual (k as a fraction)

Both operate leaf-wise on pytrees and are pure-jnp (usable inside shard_map).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"            # none | int8 | topk
    topk_frac: float = 0.05


def init_error(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def topk_mask(x: jax.Array, frac: float) -> jax.Array:
    flat = jnp.abs(x.reshape(-1))
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def encode(cfg: CompressionConfig, delta: Any, error: Any
           ) -> tuple[Any, Any, dict]:
    """Returns (transmitted delta, new error-feedback state, stats)."""
    if cfg.kind == "none":
        return delta, error, {"compression_ratio": 1.0}

    sent_bits = 0
    raw_bits = 0

    def leaf(d, e):
        nonlocal sent_bits, raw_bits
        d = d.astype(jnp.float32) + e                   # apply carried error
        raw_bits += d.size * 32
        if cfg.kind == "int8":
            q, scale = compress_int8(d)
            sent = decompress_int8(q, scale)
            sent_bits += d.size * 8 + 32
        elif cfg.kind == "topk":
            mask = topk_mask(d, cfg.topk_frac)
            sent = d * mask
            sent_bits += int(d.size * cfg.topk_frac) * (32 + 32)
        else:
            raise ValueError(cfg.kind)
        return sent, d - sent                            # new error residual

    flat_d, tdef = jax.tree_util.tree_flatten(delta)
    flat_e = tdef.flatten_up_to(error)
    out = [leaf(d, e) for d, e in zip(flat_d, flat_e)]
    sent_tree = tdef.unflatten([o[0] for o in out])
    err_tree = tdef.unflatten([o[1] for o in out])
    ratio = raw_bits / max(sent_bits, 1)
    return sent_tree, err_tree, {"compression_ratio": ratio}
