from repro.optim import adamw, compression, schedules
from repro.optim.consensus import (ConsensusConfig, ConsensusTrainer,
                                   TrainState)

__all__ = ["adamw", "compression", "schedules", "ConsensusConfig",
           "ConsensusTrainer", "TrainState"]
