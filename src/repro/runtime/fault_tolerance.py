"""Runtime fault tolerance: retries, straggler detection, elastic rescale.

A unique property of consensus-ADMM training (vs. a global all-reduce): the
optimizer *tolerates a missing neighbor* — dropping an edge or a node leaves
a smaller but still-valid consensus problem. Two elastic paths exploit that:

  * **layout-preserving** (preferred, ``ElasticController.drop_preserving``):
    the lost pod becomes a masked ghost row in the dynamic-topology state
    (``repro.topology``) — array shapes, jit caches and the fused step all
    survive untouched; the runtime rewires the surviving nodes through the
    compiled offset superset and asserts connectivity. A node loss is a
    topology epoch, not a crash.
  * **shrinking** (legacy, ``ElasticController.drop``): rebuild the graph at
    J-1 (``core.graph.drop_node``) and remap the surviving eta/budget edges
    — a restart from checkpoint into the smaller mesh; a synchronous-DP
    framework would have to abort the step either way.

Wall-clock monitoring is injectable (``clock``) so straggler logic is unit-
testable on CPU without real slow hosts.

Under the async executor (``repro.async_exec``) straggler detection and
churn UNIFY: a straggler is just a node whose edges aged out. The
bounded-staleness clocks (``TopologyState.age``) already gate a slow
node's edges round by round — transiently, with zero-kick absorption, and
self-healing on the next arrival. ``aged_out_nodes`` reads those same
clocks at a patience multiple of the staleness bound: a node that stays
aged out that long has effectively left the fleet, and ghosting it via
``ElasticController.drop_preserving`` merely makes permanent (and
backbone-repairs) what the staleness gates were already doing. No second
wall-clock heuristic, one signal for both mechanisms.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

from repro.core.graph import Graph, drop_node
from repro.core.penalty import PenaltyState


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 3
    backoff_s: float = 0.5
    backoff_mult: float = 2.0
    retryable: tuple = (RuntimeError, OSError)


def with_retries(fn: Callable, policy: RetryPolicy,
                 *, on_retry: Callable[[int, Exception], None] | None = None,
                 sleep: Callable[[float], None] = time.sleep):
    """Wrap a step function in bounded retry-with-backoff."""
    def wrapped(*args, **kwargs):
        delay = policy.backoff_s
        for attempt in range(policy.max_retries + 1):
            try:
                return fn(*args, **kwargs)
            except policy.retryable as e:
                if attempt == policy.max_retries:
                    raise
                if on_retry is not None:
                    on_retry(attempt, e)
                sleep(delay)
                delay *= policy.backoff_mult
        raise AssertionError("unreachable")
    return wrapped


class StragglerMonitor:
    """EMA step-time tracker with outlier flagging per node.

    In a real deployment each host reports its step wall time; here the
    ``observe`` call takes the per-node durations (tests inject synthetic
    delays). A node whose EMA exceeds ``threshold`` x the fleet median is
    flagged; the caller decides between (a) dropping its edges for the next
    consensus round and (b) a full elastic rescale.
    """

    def __init__(self, num_nodes: int, *, alpha: float = 0.3,
                 threshold: float = 2.0, patience: int = 3):
        self.ema = np.zeros(num_nodes)
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.strikes = np.zeros(num_nodes, dtype=int)
        self._initialized = False

    def observe(self, durations: np.ndarray) -> list[int]:
        durations = np.asarray(durations, dtype=float)
        if not self._initialized:
            self.ema = durations.copy()
            self._initialized = True
        else:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * durations
        med = float(np.median(self.ema))
        slow = self.ema > self.threshold * max(med, 1e-9)
        self.strikes = np.where(slow, self.strikes + 1, 0)
        return [int(i) for i in np.nonzero(
            self.strikes >= self.patience)[0]]


def aged_out_nodes(topo_state, *, max_staleness: int,
                   patience: int = 4) -> list[int]:
    """Nodes whose EVERY active edge has aged past ``patience x bound``.

    The async executor's staleness clocks (``TopologyState.age``) are the
    straggler signal: an edge older than ``max_staleness`` is already
    transiently gated by the executor; a node whose freshest edge is
    ``patience`` times older than the bound is not late, it is gone —
    return it for a layout-preserving ghost drop. Symmetrized ages (max of
    both directions) so a half-broken link counts as broken.
    """
    age = np.asarray(topo_state.age)
    age = np.maximum(age, age.T)
    mask = np.asarray(topo_state.mask)
    alive = np.asarray(topo_state.node_alive)
    cutoff = patience * max(max_staleness, 1)
    out = []
    for i in range(age.shape[0]):
        if not alive[i]:
            continue
        edges = mask[i] & alive
        edges[i] = False
        if edges.any() and age[i][edges].min() > cutoff:
            out.append(i)
    return out


def shrink_penalty_state(state: PenaltyState, victim: int) -> PenaltyState:
    """Remove a node's rows/cols from the [J, J] penalty state.

    Surviving edges keep their eta / spent budget / top-up counters — the
    adaptation history is preserved across the rescale.
    """
    import jax.numpy as jnp
    keep = jnp.asarray([i for i in range(state.eta.shape[0]) if i != victim])

    def cut(x):
        if x.ndim == 2:
            return x[jnp.ix_(keep, keep)]
        if x.ndim == 1:
            return x[keep]
        return x

    return PenaltyState(eta=cut(state.eta), cum_tau=cut(state.cum_tau),
                        budget=cut(state.budget), n_incr=cut(state.n_incr),
                        f_prev=cut(state.f_prev), t=state.t)


@dataclasses.dataclass
class ElasticEvent:
    step: int
    victim: int
    old_nodes: int
    new_nodes: int
    mode: str = "shrink"          # shrink | preserve


class ElasticController:
    """Drives the consensus-problem rescale when a node is lost.

    Two modes (module docstring): ``drop`` shrinks the graph and penalty
    state to J-1 (the launcher restarts into the smaller mesh); with a
    ``topology`` runtime attached, ``drop_preserving`` instead ghosts the
    victim in the traced TopologyState — shapes, jit caches and the fused
    step survive, so training continues without a restart. The controller
    decides *what the new consensus problem is* either way.
    """

    def __init__(self, graph: Graph, *, topology=None):
        self.graph = graph
        self.topology = topology          # optional TopologyRuntime
        self.events: list[ElasticEvent] = []

    def drop(self, victim: int, penalty: PenaltyState, step: int
             ) -> tuple[Graph, PenaltyState]:
        old = self.graph.num_nodes
        self.graph = drop_node(self.graph, victim)
        new_pen = shrink_penalty_state(penalty, victim)
        self.events.append(ElasticEvent(step=step, victim=victim,
                                        old_nodes=old,
                                        new_nodes=self.graph.num_nodes))
        return self.graph, new_pen

    def drop_preserving(self, victim: int, topo_state, step: int):
        """Layout-preserving drop -> new TopologyState (no shapes change).

        The penalty state is NOT shrunk: the engine masks ghost rows/cols
        out of the penalty adjacency, preserving surviving edges' full
        adaptation history at the original [J, J] layout.
        """
        if self.topology is None:
            raise ValueError("drop_preserving needs a TopologyRuntime "
                             "(ElasticController(graph, topology=...))")
        new_state = self.topology.drop_node(topo_state, victim)
        alive = int(np.asarray(new_state.node_alive).sum())
        self.events.append(ElasticEvent(step=step, victim=victim,
                                        old_nodes=self.graph.num_nodes,
                                        new_nodes=alive, mode="preserve"))
        return new_state
