from repro.runtime.fault_tolerance import (ElasticController, RetryPolicy,
                                           StragglerMonitor, aged_out_nodes,
                                           shrink_penalty_state, with_retries)

__all__ = ["ElasticController", "RetryPolicy", "StragglerMonitor",
           "aged_out_nodes", "shrink_penalty_state", "with_retries"]
