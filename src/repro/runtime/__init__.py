from repro.runtime.fault_tolerance import (ElasticController, RetryPolicy,
                                           StragglerMonitor,
                                           shrink_penalty_state, with_retries)

__all__ = ["ElasticController", "RetryPolicy", "StragglerMonitor",
           "shrink_penalty_state", "with_retries"]
