from repro.checkpoint.checkpoint import (latest_steps, restore, save,
                                         save_async, wait_pending)

__all__ = ["latest_steps", "restore", "save", "save_async", "wait_pending"]
