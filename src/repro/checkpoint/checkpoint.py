"""Fault-tolerant checkpointing (no orbax in this container — built here).

Design for restart-after-failure on big clusters:
  * atomic: write to ``<dir>/tmp.<step>``, fsync, rename to ``step_<n>`` —
    a crash mid-write never corrupts the latest checkpoint;
  * self-describing: a msgpack manifest stores the pytree structure, dtypes,
    shapes, plus user metadata (data cursor, mesh shape, graph topology,
    penalty scheme) so restore can validate compatibility;
  * keep-k retention with garbage collection;
  * async: ``save_async`` snapshots to host memory then writes on a thread so
    the train loop is blocked only for the device->host copy;
  * sharding-aware restore: pass shardings to place leaves directly.
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import msgpack
import numpy as np

_MANIFEST = "manifest.msgpack"

# numpy can't savez extended dtypes (bfloat16 etc.) — store them as raw
# uint views and restore via the manifest's logical dtype.
_EXT_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_storable(a: np.ndarray) -> np.ndarray:
    name = a.dtype.name
    if name in _EXT_DTYPES:
        return a.view(_EXT_DTYPES[name][1])
    return a


def _from_storable(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXT_DTYPES:
        return a.view(_EXT_DTYPES[dtype_name][0])
    return a


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef, str(treedef)


def save(ckpt_dir: str, step: int, tree: Any, *, metadata: dict | None = None,
         keep: int = 3) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _, treedef_str = _flatten(tree)
    arrs = [np.asarray(leaf) for leaf in leaves]
    np.savez(os.path.join(tmp, "leaves.npz"),
             **{f"leaf_{i}": _to_storable(a) for i, a in enumerate(arrs)})
    manifest = {
        "step": step,
        "treedef": treedef_str,
        "num_leaves": len(arrs),
        "shapes": [list(a.shape) for a in arrs],
        "dtypes": [str(a.dtype) for a in arrs],
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, _MANIFEST), "wb") as f:
        f.write(msgpack.packb(manifest))
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


_PENDING: list[threading.Thread] = []


def save_async(ckpt_dir: str, step: int, tree: Any, *,
               metadata: dict | None = None, keep: int = 3
               ) -> threading.Thread:
    """Device->host copy now; disk write on a background thread."""
    host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
    t = threading.Thread(
        target=save, args=(ckpt_dir, step, host_tree),
        kwargs={"metadata": metadata, "keep": keep}, daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    for t in list(_PENDING):
        t.join()
        _PENDING.remove(t)


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            # ignore half-written tmp dirs (never renamed)
            if os.path.exists(os.path.join(ckpt_dir, name, _MANIFEST)):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def restore(ckpt_dir: str, tree_like: Any, *, step: int | None = None,
            shardings: Any | None = None) -> tuple[Any, dict]:
    """Restore the newest (or given) step into the structure of tree_like."""
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step = steps[-1] if step is None else step
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, _MANIFEST), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "leaves.npz"))
    leaves_ref, treedef = jax.tree_util.tree_flatten(tree_like)
    if manifest["num_leaves"] != len(leaves_ref):
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves, expected "
            f"{len(leaves_ref)} — incompatible state structure")
    arrs = [_from_storable(data[f"leaf_{i}"], manifest["dtypes"][i])
            for i in range(manifest["num_leaves"])]
    for i, (a, ref) in enumerate(zip(arrs, leaves_ref)):
        if tuple(a.shape) != tuple(np.shape(ref)):
            raise ValueError(f"leaf {i}: checkpoint shape {a.shape} != "
                             f"expected {np.shape(ref)}")
    if shardings is not None:
        sh_leaves = treedef.flatten_up_to(shardings)
        placed = [jax.device_put(a, s) for a, s in zip(arrs, sh_leaves)]
    else:
        placed = [jax.numpy.asarray(a) for a in arrs]
    return treedef.unflatten(placed), manifest["metadata"]
