"""Core: the paper's consensus-ADMM engine with adaptive penalty schedules."""
from repro.core.admm import ConsensusADMM, ConsensusState, consensus_error
from repro.core.graph import (Graph, TOPOLOGIES, build_graph, chain_graph,
                              cluster_graph, complete_graph,
                              connected_components, drop_node,
                              expander_graph, ring_graph, star_graph,
                              torus_graph)
from repro.core.penalty import (SCHEMES, PenaltyConfig, PenaltyState,
                                budget_exhausted, compute_tau, effective_eta,
                                init_penalty_state, update_penalty)
from repro.core.residuals import (Residuals, local_residuals, neighbor_mean,
                                  node_eta)

__all__ = [
    "ConsensusADMM", "ConsensusState", "consensus_error",
    "Graph", "TOPOLOGIES", "build_graph", "chain_graph", "cluster_graph",
    "complete_graph", "connected_components", "drop_node", "expander_graph",
    "ring_graph", "star_graph", "torus_graph",
    "SCHEMES", "PenaltyConfig", "PenaltyState", "budget_exhausted",
    "compute_tau", "effective_eta", "init_penalty_state", "update_penalty",
    "Residuals", "local_residuals", "neighbor_mean", "node_eta",
]
