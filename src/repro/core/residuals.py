"""Local primal/dual residuals for fully-decentralized ADMM (paper eq. 5).

    ||r_i||^2 = ||theta_i - theta_bar_i||^2
    ||s_i||^2 = eta_i^2 ||theta_bar_i - theta_bar_i^{t-1}||^2
    theta_bar_i = (1/|B_i|) sum_{j in B_i} theta_j

Unlike the global residuals of Boyd et al. used by He-Yang-Wang (eq. 4), these
are computable at node i from one neighbor exchange — the key change that makes
the VP schedule fully decentralized (§3.1).

Two layouts are supported:
  * dense: parameters stacked on a leading node axis ``[J, ...]`` (single-host
    reproduction path — PPCA, synthetic convex problems);
  * pytree: each node holds a pytree; norms reduce over all leaves.

``adj`` may be a TRACED dynamic-topology mask (``repro.topology``) instead of
the static adjacency — everything here is mask-shape-agnostic. A row with no
active edges (a gated-out or ghost node) gets theta_bar = 0 (the degree
clamps to 1), so its "residual" equals its parameter norm; callers that
report or gate on residuals should mask ghost rows out (the trainer does).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Residuals(NamedTuple):
    r_norm: jax.Array          # [J]  primal residual norm per node
    s_norm: jax.Array          # [J]  dual residual norm per node
    theta_bar: Any             # [J, ...] (or pytree) neighbor average, for t+1


def _tree_sq_norm_per_node(tree: Any) -> jax.Array:
    """Sum of squares over every leaf, keeping the leading node axis."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = None
    for leaf in leaves:
        sq = jnp.sum(jnp.square(leaf.astype(jnp.float32)),
                     axis=tuple(range(1, leaf.ndim)))
        total = sq if total is None else total + sq
    assert total is not None, "empty pytree"
    return total


def neighbor_mean(theta: Any, adj: jax.Array) -> Any:
    """theta_bar_i = mean_{j in B_i} theta_j, per leaf. theta leaves: [J, ...]."""
    adj_f = adj.astype(jnp.float32)
    deg = jnp.maximum(adj_f.sum(axis=1), 1.0)  # [J]

    def per_leaf(leaf):
        flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
        bar = (adj_f @ flat) / deg[:, None]
        return bar.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree_util.tree_map(per_leaf, theta)


def local_residuals(theta: Any, theta_bar_prev: Any, adj: jax.Array,
                    eta_node: jax.Array) -> Residuals:
    """Compute eq. (5) for all nodes at once.

    Args:
      theta: pytree with leading node axis [J, ...] on every leaf.
      theta_bar_prev: same structure — theta_bar from the previous iteration.
      adj: [J, J] bool adjacency.
      eta_node: [J] the per-node penalty entering the dual residual. For
        edge-based schemes pass the mean eta over the node's edges.

    Returns:
      Residuals(r_norm [J], s_norm [J], theta_bar pytree).
    """
    theta_bar = neighbor_mean(theta, adj)
    diff_primal = jax.tree_util.tree_map(lambda a, b: a - b, theta, theta_bar)
    diff_dual = jax.tree_util.tree_map(lambda a, b: a - b, theta_bar,
                                       theta_bar_prev)
    r = jnp.sqrt(_tree_sq_norm_per_node(diff_primal))
    s = eta_node.astype(jnp.float32) * jnp.sqrt(_tree_sq_norm_per_node(diff_dual))
    return Residuals(r_norm=r, s_norm=s, theta_bar=theta_bar)


def node_eta(eta_edges: jax.Array, adj: jax.Array) -> jax.Array:
    """Collapse per-edge eta_ij to a per-node eta_i (mean over own edges)."""
    adj_f = adj.astype(eta_edges.dtype)
    deg = jnp.maximum(adj_f.sum(axis=1), 1.0)
    return (eta_edges * adj_f).sum(axis=1) / deg
