"""Communication-graph topologies for consensus ADMM.

The paper (AAAI'16, §2) formulates consensus optimization on a connected graph
G = (V, E); the penalty schemes of §3 attach state to *directed* edges e_ij.
This module builds the topologies used in the paper's experiments (complete,
ring, cluster — §5.1) plus extras needed at production scale (star, chain,
expander, torus) and exposes them in two forms:

  * a dense boolean adjacency matrix ``adj[J, J]`` (vmappable; used by the
    D-PPCA reproduction where all nodes live on one host), and
  * neighbor permutation lists (used by the shard_map/collective_permute
    implementation of the consensus exchange on a real mesh).

Everything here is static Python/NumPy — graph structure is trace-time
constant; only penalties/params are traced.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

TOPOLOGIES = (
    "complete",
    "ring",
    "cluster",
    "star",
    "chain",
    "torus",
    "expander",
)


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: jit-cache key
class Graph:
    """A static, connected, undirected communication graph.

    Attributes:
      num_nodes: J, the number of ADMM nodes.
      adj: (J, J) bool ndarray, symmetric, zero diagonal.
      name: topology name for logging.
    """

    num_nodes: int
    adj: np.ndarray
    name: str = "custom"

    def __post_init__(self):
        a = np.asarray(self.adj, dtype=bool)
        if a.shape != (self.num_nodes, self.num_nodes):
            raise ValueError(f"adjacency shape {a.shape} != J={self.num_nodes}")
        if np.any(np.diag(a)):
            raise ValueError("self-loops not allowed")
        if not np.array_equal(a, a.T):
            raise ValueError("graph must be undirected (symmetric adjacency)")
        if self.num_nodes > 1 and not self.is_connected():
            raise ValueError(f"topology {self.name!r} is not connected")

    # -- structure queries ---------------------------------------------------
    def neighbors(self, i: int) -> np.ndarray:
        return np.nonzero(self.adj[i])[0]

    @property
    def degrees(self) -> np.ndarray:
        return self.adj.sum(axis=1).astype(np.int32)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max()) if self.num_nodes > 1 else 0

    @property
    def num_edges(self) -> int:
        return int(self.adj.sum()) // 2

    def directed_edges(self) -> list[tuple[int, int]]:
        """All ordered pairs (i, j) with e_ij in E — one per eta_ij."""
        ii, jj = np.nonzero(self.adj)
        return list(zip(ii.tolist(), jj.tolist()))

    def is_connected(self) -> bool:
        reach = np.zeros(self.num_nodes, dtype=bool)
        reach[0] = True
        frontier = [0]
        while frontier:
            i = frontier.pop()
            for j in np.nonzero(self.adj[i])[0]:
                if not reach[j]:
                    reach[j] = True
                    frontier.append(int(j))
        return bool(reach.all())

    def laplacian(self) -> np.ndarray:
        return np.diag(self.degrees.astype(np.float64)) - self.adj.astype(np.float64)

    def algebraic_connectivity(self) -> float:
        """Fiedler value — the paper observes VP degrades as this shrinks."""
        evals = np.linalg.eigvalsh(self.laplacian())
        return float(evals[1]) if self.num_nodes > 1 else 0.0

    # -- collective-friendly views -------------------------------------------
    def permutation_rounds(self) -> list[list[tuple[int, int]]]:
        """Decompose directed edges into rounds of disjoint-source permutations.

        Each round is a list of (src, dst) pairs where every src appears at
        most once — directly usable as a ``lax.ppermute`` schedule.  Greedy
        edge coloring; at most ``max_degree`` rounds for the topologies here
        (each round sends in one direction, the reverse direction is the same
        round with pairs swapped, also a valid permutation).
        """
        rounds: list[list[tuple[int, int]]] = []
        remaining = {(i, j) for i, j in self.directed_edges()}
        while remaining:
            used_src: set[int] = set()
            used_dst: set[int] = set()
            round_pairs: list[tuple[int, int]] = []
            for (i, j) in sorted(remaining):
                if i not in used_src and j not in used_dst:
                    round_pairs.append((i, j))
                    used_src.add(i)
                    used_dst.add(j)
            remaining -= set(round_pairs)
            rounds.append(round_pairs)
        return rounds

    def neighbor_offsets_ring(self) -> list[int]:
        """For circulant graphs: neighbor index offsets (mod J)."""
        offs = set()
        for j in self.neighbors(0):
            offs.add((int(j) - 0) % self.num_nodes)
        return sorted(offs)


# --- constructors -------------------------------------------------------------


def complete_graph(j: int) -> Graph:
    adj = ~np.eye(j, dtype=bool)
    if j == 1:
        adj = np.zeros((1, 1), dtype=bool)
    return Graph(j, adj, "complete")


def ring_graph(j: int) -> Graph:
    adj = np.zeros((j, j), dtype=bool)
    for i in range(j):
        adj[i, (i + 1) % j] = True
        adj[(i + 1) % j, i] = True
    np.fill_diagonal(adj, False)
    return Graph(j, adj, "ring")


def chain_graph(j: int) -> Graph:
    adj = np.zeros((j, j), dtype=bool)
    for i in range(j - 1):
        adj[i, i + 1] = adj[i + 1, i] = True
    return Graph(j, adj, "chain")


def star_graph(j: int) -> Graph:
    adj = np.zeros((j, j), dtype=bool)
    adj[0, 1:] = adj[1:, 0] = True
    return Graph(j, adj, "star")


def cluster_graph(j: int) -> Graph:
    """Two complete graphs of sizes ceil(J/2), floor(J/2) linked by one edge.

    This is the paper's "cluster" topology (§5.1): "a connected graph consists
    of two complete graphs linked with an edge".
    """
    if j < 2:
        return complete_graph(j)
    a = (j + 1) // 2
    adj = np.zeros((j, j), dtype=bool)
    adj[:a, :a] = ~np.eye(a, dtype=bool)
    adj[a:, a:] = ~np.eye(j - a, dtype=bool)
    # bridge between node a-1 and node a
    adj[a - 1, a] = adj[a, a - 1] = True
    return Graph(j, adj, "cluster")


def torus_graph(rows: int, cols: int) -> Graph:
    j = rows * cols
    adj = np.zeros((j, j), dtype=bool)
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for (dr, dc) in ((0, 1), (1, 0)):
                n = ((r + dr) % rows) * cols + (c + dc) % cols
                if n != i:
                    adj[i, n] = adj[n, i] = True
    return Graph(j, adj, "torus")


def expander_graph(j: int, degree: int = 4, seed: int = 0) -> Graph:
    """Circulant pseudo-expander: ring + power-of-two chords.

    Deterministic (seed picks chord phase), degree-bounded, diameter
    O(log J) — the topology we recommend for J in the hundreds-of-pods
    regime where complete is too chatty and ring mixes too slowly.
    """
    del seed
    adj = ring_graph(j).adj.copy()
    hop = 2
    added = 2
    while added < degree and hop < j:
        for i in range(j):
            adj[i, (i + hop) % j] = adj[(i + hop) % j, i] = True
        added += 2
        hop *= 2
    np.fill_diagonal(adj, False)
    return Graph(j, adj, "expander")


def build_graph(name: str, j: int, **kw) -> Graph:
    if name == "complete":
        return complete_graph(j)
    if name == "ring":
        return ring_graph(j)
    if name == "cluster":
        return cluster_graph(j)
    if name == "star":
        return star_graph(j)
    if name == "chain":
        return chain_graph(j)
    if name == "torus":
        rows = kw.get("rows") or int(np.sqrt(j))
        if j % rows:
            raise ValueError(f"torus: J={j} not divisible by rows={rows}")
        return torus_graph(rows, j // rows)
    if name == "expander":
        return expander_graph(j, degree=kw.get("degree", 4))
    raise ValueError(f"unknown topology {name!r}; options: {TOPOLOGIES}")


def connected_components(adj: np.ndarray) -> list[list[int]]:
    """Connected components of a boolean adjacency (sorted node lists)."""
    adj = np.asarray(adj, dtype=bool)
    n = adj.shape[0]
    seen = np.zeros(n, dtype=bool)
    comps: list[list[int]] = []
    for s in range(n):
        if seen[s]:
            continue
        comp = [s]
        seen[s] = True
        frontier = [s]
        while frontier:
            i = frontier.pop()
            for j in np.nonzero(adj[i])[0]:
                if not seen[j]:
                    seen[j] = True
                    comp.append(int(j))
                    frontier.append(int(j))
        comps.append(sorted(comp))
    return comps


def drop_node(g: Graph, node: int) -> Graph:
    """Elastic-rescale helper: remove a failed node, keep the graph connected.

    If removal disconnects the graph, repair with a spanning chain over the
    resulting COMPONENTS (one bridge edge per adjacent component pair),
    choosing each bridge endpoint among the dropped node's former neighbors
    when possible — the cheapest repair that preserves locality. Chaining
    components (rather than chaining the former neighbors pairwise) both
    adds the minimal number of edges and cannot leave a star-like cut
    region disconnected. Connectivity is asserted before returning.
    """
    keep = [i for i in range(g.num_nodes) if i != node]
    adj = g.adj[np.ix_(keep, keep)].copy()
    if len(keep) > 1:
        comps = connected_components(adj)
        if len(comps) > 1:
            old_nbrs = {keep.index(i) for i in g.neighbors(node)
                        if i != node}
            # one representative per component, preferring former neighbors
            reps = [min(set(c) & old_nbrs) if set(c) & old_nbrs else c[0]
                    for c in comps]
            for a, b in zip(reps[:-1], reps[1:]):
                adj[a, b] = adj[b, a] = True
    # Graph.__post_init__ asserts connectivity of the repaired result
    return Graph(len(keep), adj, g.name)
