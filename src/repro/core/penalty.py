"""Adaptive penalty schedules for consensus ADMM — the paper's contribution.

Implements all six schemes from Song, Yoon & Pavlovic (AAAI 2016):

  * ``fixed``    — standard ADMM, constant eta (the baseline).
  * ``vp``       — §3.1 ADMM-VP: He-Yang-Wang residual balancing (eq. 4) made
                   fully decentralized with *local* residuals (eq. 5) and a
                   homogeneous reset to eta0 after ``t_reset`` iterations.
  * ``ap``       — §3.2 ADMM-AP: per-edge eta_ij = eta0 * (1 + tau_ij),
                   tau_ij = kappa_i(theta_i)/kappa_i(theta_j) - 1 from
                   normalized local-objective probes (eq. 6–8). Parameter-free.
  * ``nap``      — §3.3 ADMM-NAP: AP gated by a per-edge *budget* on the total
                   spent |tau| (eq. 9), with the budget itself adapted by a
                   geometric top-up while the local objective still moves
                   (eq. 10); total budget bounded by T/(1-alpha) (eq. 11).
  * ``vp_ap``    — §3.4 eq. (12): residual-balancing x2 / x0.5 composed with
                   the AP factor, multiplicative on eta_ij^t, reset at t_max.
  * ``vp_nap``   — §3.4: eq. (12) gated by the NAP budget instead of t_max.

State is dense ``[J, J]`` (edge e_ij at [i, j]) masked by the graph adjacency —
the single-host reproduction path. The distributed trainer uses the same
functions with J = number of pods and slices rows locally under shard_map
(every update below is row-local: node i only reads F[i, :], r[i], s[i]).

All functions are pure and jit/vmap-friendly; J is static.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

SCHEMES = ("fixed", "vp", "ap", "nap", "vp_ap", "vp_nap")


@dataclasses.dataclass(frozen=True)
class PenaltyConfig:
    """Hyper-parameters for the penalty schedule.

    Paper-suggested defaults: eta0=10 (§5), mu=10, tau_fixed=1 (He et al. via
    §2.1), t_max=50 (§3.2, following [10]), t_reset=50 (§3.1 — the paper fixes
    "a fixed number of iterations"; unspecified, we align it with t_max).
    ``budget_init`` is the NAP initial budget T ("one can choose any small
    value of T", §5.2); alpha, beta in (0,1) per eq. (10).
    ``relative_beta`` applies beta to the *relative* objective change — the
    paper's |f^t - f^{t-1}| > beta is scale-dependent; relative matches the
    paper's own relative-change convergence criterion (§5) and keeps beta
    meaningful across problems. Set False for the literal rule.
    """

    scheme: str = "fixed"
    eta0: float = 10.0
    mu: float = 10.0
    tau_fixed: float = 1.0
    t_max: int = 50
    t_reset: int = 50
    budget_init: float = 1.0
    alpha: float = 0.5
    beta: float = 1e-3
    relative_beta: bool = True
    eta_min: float = 1e-6
    eta_max: float = 1e6

    def __post_init__(self):
        if self.scheme not in SCHEMES:
            raise ValueError(f"scheme {self.scheme!r} not in {SCHEMES}")

    @property
    def is_edge_based(self) -> bool:
        return self.scheme in ("ap", "nap", "vp_ap", "vp_nap")

    @property
    def uses_residuals(self) -> bool:
        return self.scheme in ("vp", "vp_ap", "vp_nap")

    @property
    def uses_objective_probes(self) -> bool:
        return self.scheme in ("ap", "nap", "vp_ap", "vp_nap")

    @property
    def uses_budget(self) -> bool:
        return self.scheme in ("nap", "vp_nap")


class PenaltyState(NamedTuple):
    """Traced per-edge penalty state. All arrays are [J, J] except f_prev [J]."""

    eta: jax.Array        # [J, J] current per-edge penalty eta_ij
    cum_tau: jax.Array    # [J, J] spent budget  sum_u |tau_ij^u|   (eq. 9 lhs)
    budget: jax.Array     # [J, J] budget upper bound  T_ij^t        (eq. 10)
    n_incr: jax.Array     # [J, J] int32 top-up counter n            (eq. 10)
    f_prev: jax.Array     # [J]    f_i(theta_i^{t-1}) for the beta test
    t: jax.Array          # []     int32 iteration counter


def init_penalty_state(cfg: PenaltyConfig, num_nodes: int,
                       dtype=jnp.float32) -> PenaltyState:
    j = num_nodes
    return PenaltyState(
        eta=jnp.full((j, j), cfg.eta0, dtype),
        cum_tau=jnp.zeros((j, j), dtype),
        budget=jnp.full((j, j), cfg.budget_init, dtype),
        n_incr=jnp.zeros((j, j), jnp.int32),
        f_prev=jnp.full((j,), jnp.inf, dtype),
        t=jnp.zeros((), jnp.int32),
    )


def compute_tau(adj: jax.Array, f_self: jax.Array, f_nbr: jax.Array) -> jax.Array:
    """Per-edge tau_ij from normalized objective probes (eq. 7–8).

    Args:
      adj: [J, J] bool adjacency.
      f_self: [J], F[i] = f_i(theta_i^t).
      f_nbr: [J, J], F[i, j] = f_i(probe_ij) — node i's objective evaluated at
        neighbor j's parameter estimate (or at rho_ij, the edge midpoint,
        per the paper's locality remark). Only entries with adj[i,j] matter.

    Returns:
      [J, J] tau_ij in [-1/2, 1]; zero on non-edges.
    """
    big = jnp.asarray(jnp.finfo(f_nbr.dtype).max, f_nbr.dtype)
    nbr_masked_min = jnp.where(adj, f_nbr, big)
    nbr_masked_max = jnp.where(adj, f_nbr, -big)
    # eq. (8): extremes over {f_i(theta_i)} U {f_i(theta_j) : j in B_i}
    f_min = jnp.minimum(f_self, nbr_masked_min.min(axis=1))
    f_max = jnp.maximum(f_self, nbr_masked_max.max(axis=1))
    denom = jnp.maximum(f_max - f_min, jnp.finfo(f_nbr.dtype).tiny)
    # eq. (7): kappa in [1, 2]
    kappa_self = (f_self - f_min) / denom + 1.0          # [J]
    kappa_nbr = (f_nbr - f_min[:, None]) / denom[:, None] + 1.0  # [J, J]
    tau = kappa_self[:, None] / jnp.maximum(kappa_nbr, 1.0) - 1.0
    # degenerate neighborhoods (all probes equal) => tau = 0, consensus onus
    tau = jnp.where(denom <= jnp.finfo(f_nbr.dtype).tiny * 2, 0.0, tau)
    return jnp.where(adj, tau, 0.0).astype(f_nbr.dtype)


def _vp_factor(cfg: PenaltyConfig, r_norm: jax.Array, s_norm: jax.Array,
               tau: jax.Array) -> jax.Array:
    """eq. (4) decision per node i, returning the multiplicative factor [J]."""
    up = r_norm > cfg.mu * s_norm
    dn = s_norm > cfg.mu * r_norm
    grow = 1.0 + tau
    return jnp.where(up, grow, jnp.where(dn, 1.0 / grow, 1.0))


def _clip(cfg: PenaltyConfig, eta: jax.Array) -> jax.Array:
    return jnp.clip(eta, cfg.eta_min, cfg.eta_max)


@partial(jax.jit, static_argnums=0)
def update_penalty(cfg: PenaltyConfig, state: PenaltyState, *,
                   adj: jax.Array,
                   f_self: jax.Array | None = None,
                   f_nbr: jax.Array | None = None,
                   r_norm: jax.Array | None = None,
                   s_norm: jax.Array | None = None) -> PenaltyState:
    """One penalty-schedule step. Call once per ADMM (outer) iteration.

    Residuals (r_norm, s_norm: [J]) are required for vp/vp_ap/vp_nap;
    objective probes (f_self: [J], f_nbr: [J, J]) for ap/nap/vp_ap/vp_nap.
    """
    j = state.eta.shape[0]
    dtype = state.eta.dtype
    adj = adj.astype(bool)
    t = state.t

    if cfg.uses_objective_probes:
        assert f_self is not None and f_nbr is not None, cfg.scheme
        tau = compute_tau(adj, f_self.astype(dtype), f_nbr.astype(dtype))
    else:
        tau = jnp.zeros((j, j), dtype)

    if cfg.uses_residuals:
        assert r_norm is not None and s_norm is not None, cfg.scheme
        r_norm = r_norm.astype(dtype)
        s_norm = s_norm.astype(dtype)

    cum_tau, budget, n_incr = state.cum_tau, state.budget, state.n_incr

    if cfg.scheme == "fixed":
        eta = state.eta

    elif cfg.scheme == "vp":
        # eq. (4) with local residuals (eq. 5) and fixed tau; per-node eta_i
        # broadcast across the row (node i applies eta_i to all its edges).
        factor = _vp_factor(cfg, r_norm, s_norm,
                            jnp.full((j,), cfg.tau_fixed, dtype))
        eta = state.eta * factor[:, None]
        # §3.1: heterogeneous frozen penalties oscillate => homogeneous reset.
        eta = jnp.where(t >= cfg.t_reset, jnp.full_like(eta, cfg.eta0), eta)

    elif cfg.scheme == "ap":
        # eq. (6): anchored at eta0 every step, frozen to eta0 after t_max.
        eta = jnp.where(t < cfg.t_max, cfg.eta0 * (1.0 + tau),
                        jnp.full((j, j), cfg.eta0, dtype))

    elif cfg.scheme == "nap":
        # eq. (9): anchored at eta0, gated per-edge by the spent budget.
        within = cum_tau < budget
        eta = jnp.where(within, cfg.eta0 * (1.0 + tau),
                        jnp.full((j, j), cfg.eta0, dtype))
        cum_tau = cum_tau + jnp.where(within, jnp.abs(tau), 0.0)

    elif cfg.scheme == "vp_ap":
        # eq. (12): multiplicative on eta^t, x2 / x0.5 by residual balance.
        up = (r_norm > cfg.mu * s_norm)[:, None]
        dn = (s_norm > cfg.mu * r_norm)[:, None]
        scale = jnp.where(up, 2.0, jnp.where(dn, 0.5, 1.0)).astype(dtype)
        changed = scale != 1.0
        eta = jnp.where(changed, state.eta * (1.0 + tau) * scale, state.eta)
        eta = jnp.where(t >= cfg.t_max, jnp.full_like(eta, cfg.eta0), eta)

    elif cfg.scheme == "vp_nap":
        # eq. (12) gated by the eq. (9) budget; no t_max.
        up = (r_norm > cfg.mu * s_norm)[:, None]
        dn = (s_norm > cfg.mu * r_norm)[:, None]
        scale = jnp.where(up, 2.0, jnp.where(dn, 0.5, 1.0)).astype(dtype)
        within = cum_tau < budget
        apply = within & (scale != 1.0)
        eta = jnp.where(apply, state.eta * (1.0 + tau) * scale, state.eta)
        # budget pays |tau| plus the log2 of the residual scaling (the actual
        # relative change made), keeping the eq. (11) bound intact.
        spend = jnp.abs(tau) + jnp.abs(jnp.log2(scale))
        cum_tau = cum_tau + jnp.where(apply, spend, 0.0)

    else:  # pragma: no cover
        raise AssertionError(cfg.scheme)

    if cfg.uses_budget:
        assert f_self is not None
        # eq. (10): top-up T_ij by alpha^n * T while f_i still moves > beta.
        delta_f = jnp.abs(f_self - state.f_prev)
        if cfg.relative_beta:
            delta_f = delta_f / (jnp.abs(state.f_prev) + 1e-12)
        moving = (delta_f > cfg.beta) & jnp.isfinite(state.f_prev)
        exhausted = cum_tau >= budget
        topup = exhausted & moving[:, None] & adj
        # eq. (11): T + sum_{n>=1} alpha^n T = T/(1-alpha) — the initial T is
        # the n=1 term of the geometric series, so top-ups start at alpha^1 T.
        budget = budget + jnp.where(
            topup, (cfg.alpha ** (n_incr.astype(dtype) + 1.0))
            * cfg.budget_init, 0.0)
        n_incr = n_incr + topup.astype(jnp.int32)

    eta = jnp.where(adj, _clip(cfg, eta), cfg.eta0)
    f_prev = f_self.astype(dtype) if f_self is not None else state.f_prev
    return PenaltyState(eta=eta, cum_tau=cum_tau, budget=budget,
                        n_incr=n_incr, f_prev=f_prev, t=t + 1)


def staleness_damping(age: jax.Array, gamma: float) -> jax.Array:
    """Per-edge damping factor 1 / (1 + gamma * age) for stale consensus.

    The async executor's dual for a stale edge was built against a
    neighbor estimate ``age`` rounds old; applying the full adaptive eta to
    it over-penalizes disagreement that the neighbor may already have
    resolved (the explicit-rate analysis of inexact consensus bounds the
    error as O(staleness) — damping the pull by the same factor keeps the
    effective step inside that bound). ``age`` should be the SYMMETRIZED
    clock (``topology.state.sym_age``) so the damped weights stay symmetric
    and the ``sum_i lam_i = 0`` invariant survives. ``age == 0`` returns
    exactly 1.0 — fresh edges are bit-identically undamped.
    """
    a = age.astype(jnp.float32)
    return 1.0 / (1.0 + jnp.asarray(gamma, jnp.float32) * a)


def effective_eta(cfg: PenaltyConfig, state: PenaltyState,
                  adj: jax.Array, *, age: jax.Array | None = None,
                  stale_gamma: float = 0.5) -> jax.Array:
    """eta actually applied to edge (i, j) this iteration, zero on non-edges.

    With ``age`` (the [J, J] staleness clocks), the applied penalty is
    additionally damped by ``staleness_damping`` — the async executor's
    view of the schedule. A fully-gated edge (adj False) contributes 0
    regardless of its adaptation state; a just-revived edge re-enters at
    its adapted eta (the schedule kept updating it while gated — see
    ``update_penalty``'s ``adj_pen`` composition in the engines).
    """
    eta = jnp.where(adj.astype(bool), state.eta, 0.0)
    if age is not None:
        eta = eta * staleness_damping(age, stale_gamma)
    return eta


def freeze_penalty(advance: jax.Array, new: PenaltyState,
                   old: PenaltyState) -> PenaltyState:
    """Per-EDGE freeze for a fleet tick where only ``advance`` nodes ran.

    Edge entry [i, j] keeps the NEW value iff either endpoint advanced;
    it stays at the OLD value only when both endpoints were frozen. The
    earlier per-ROW freeze (frozen node i keeps its whole eta row) left
    edge (i, j) asymmetric whenever j advanced: eta[j, i] adapted while
    eta[i, j] stayed put, so the applied weight 0.5*(eta_ij + eta_ji)
    drifted from both endpoints' view of the edge. Freezing per edge keeps
    a frozen node's incident entries adapting in BOTH directions (the
    advancing neighbor's probe round is the edge's shared update), so the
    penalty matrix evolves symmetrically for symmetric schedules.

    ``f_prev`` stays per-node: it is node i's memory of its own objective
    probe, and a frozen node genuinely ran no probe.
    """
    adv = advance.astype(bool)
    keep_new = adv[:, None] | adv[None, :]               # [J, J]

    def edges(a, b):
        return jnp.where(keep_new, a, b)

    return new._replace(
        eta=edges(new.eta, old.eta),
        cum_tau=edges(new.cum_tau, old.cum_tau),
        budget=edges(new.budget, old.budget),
        n_incr=edges(new.n_incr, old.n_incr),
        f_prev=jnp.where(adv, new.f_prev, old.f_prev))


def budget_exhausted(state: PenaltyState) -> jax.Array:
    """[J, J] bool — directed edges whose eq. (9) budget is spent.

    The §4 observation ("budget gating effectively leads to an adaptive,
    dynamic network topology") made queryable: ``repro.topology``'s budget
    scheduler deactivates an edge when BOTH directions are exhausted; a
    top-up (eq. 10) raises T_ij above cum_tau and revives it.
    """
    return state.cum_tau >= state.budget
