"""Generic consensus-ADMM engine over pytrees (single-host, vmapped nodes).

Solves  min_theta  sum_i f_i(theta_i)  s.t. theta_i = rho_ij, rho_ij = theta_j
on a static graph, with any of the six penalty schedules of the paper.

We use the standard fully-decentralized form (Forero et al. '11; Yoon &
Pavlovic '12) in which the edge auxiliaries rho_ij are eliminated analytically
(rho_ij = (theta_i + theta_j)/2) and each node keeps a single Lagrange
multiplier lam_i. One outer iteration (paper Algorithm 1, with the PPCA
specifics abstracted away) is:

  1. theta_i^{t+1} = argmin_th  f_i(th) + 2 <lam_i, th>
                       + sum_{j in B_i} eta_ij^t ||th - (theta_i^t+theta_j^t)/2||^2
  2. broadcast theta_i^{t+1} to neighbors
  3. lam_i^{t+1} = lam_i^t + 1/2 sum_j eta_ij^t (theta_i^{t+1} - theta_j^{t+1})
  4. update eta_ij (and budget T_ij) per the configured scheme

The argmin in (1) is delegated to a ``local_solver`` — closed-form for
quadratic losses and for the PPCA M-step, K gradient steps otherwise.

This dense engine is the reproduction/validation path (all J nodes in one
array, leading axis = node). The sharded multi-pod trainer in
``repro.optim.consensus`` reuses the same penalty/residual modules with the
node axis mapped onto the device mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial, cached_property
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import residuals as res_lib
from repro.core.graph import Graph
from repro.core.penalty import (PenaltyConfig, PenaltyState,
                                init_penalty_state, update_penalty)

PyTree = Any
# f(data_i, theta_i) -> scalar local objective for one node (unbatched).
ObjectiveFn = Callable[[PyTree, PyTree], jax.Array]
# local_solver(data_i, theta_i, lam_i, eta_row, midpoint_i) -> new theta_i,
# where midpoint_i is the pytree of eta-weighted neighbor midpoint pulls.
LocalSolver = Callable[..., PyTree]


class ConsensusState(NamedTuple):
    theta: PyTree          # leaves [J, ...] — per-node parameter estimates
    lam: PyTree            # leaves [J, ...] — per-node multipliers lam_i
    theta_bar: PyTree      # leaves [J, ...] — previous neighbor average
    penalty: PenaltyState
    t: jax.Array           # [] int32
    topo: Any = None       # TopologyState when a topology_cfg is configured


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: jit-cache key
class ConsensusADMM:
    """Configurable consensus-ADMM driver.

    Attributes:
      objective: local objective f_i (same fn for all nodes; data differs).
      penalty_cfg: which of the six schedules to run.
      graph: static communication graph.
      inner_steps / inner_lr: gradient inner solver settings (used when no
        closed-form ``local_solver`` is supplied).
      probe_midpoint: evaluate kappa at rho_ij=(theta_i+theta_j)/2 (the
        paper's locality remark in §3.2) instead of at theta_j directly.
      degree_normalize: scale each edge's applied penalty by
        (J-1)/sqrt(deg_i deg_j), so a node's total consensus pull matches
        the complete graph's regardless of topology. Complete graphs are
        unchanged (scale = 1); low-connectivity graphs (expander, ring)
        converge instead of crawling. Symmetric, so the sum_i lam_i = 0
        dual invariant survives. Set False for the paper's literal,
        unnormalized weighting. (The paper-figure reproductions —
        fig2/fig3/Hopkins — run on ``repro.ppca.DPPCA``, which has its own
        step and is NOT affected by this default.)
      topology_cfg: optional ``repro.topology.TopologyConfig`` — runs the
        dynamic-topology schedulers on the dense path: the traced edge
        mask replaces the static adjacency everywhere in the step.
    """

    objective: ObjectiveFn
    penalty_cfg: PenaltyConfig
    graph: Graph
    inner_steps: int = 10
    inner_lr: float = 0.05
    probe_midpoint: bool = False
    local_solver: LocalSolver | None = None
    degree_normalize: bool = True
    topology_cfg: Any = None

    def __post_init__(self):
        if self.topology_cfg is not None:
            self.topology_cfg.validate_penalty(self.penalty_cfg)

    @cached_property
    def _topo_rt(self):
        """Lazy TopologyRuntime (None when no topology_cfg configured).

        The dense path has no permute schedule, so churn repair may draw
        from ANY node pair (the engine is constrained to its compiled
        circulant offset superset instead).
        """
        if self.topology_cfg is None:
            return None
        from repro.topology import TopologyRuntime
        j = self.graph.num_nodes
        return TopologyRuntime(self.graph, self.topology_cfg,
                               edge_universe=~np.eye(j, dtype=bool))

    @cached_property
    def _edge_scale(self) -> jax.Array:
        """[J, J] symmetric degree-compensation factors (ones when off)."""
        j = self.graph.num_nodes
        if not self.degree_normalize or j <= 1:
            return jnp.ones((j, j), jnp.float32)
        deg = np.maximum(self.graph.degrees.astype(np.float64), 1.0)
        scale = (j - 1) / np.sqrt(deg[:, None] * deg[None, :])
        return jnp.asarray(scale, jnp.float32)

    # -- initialization --------------------------------------------------------
    def init(self, theta0: PyTree) -> ConsensusState:
        """theta0: pytree with leading node axis [J, ...] on every leaf."""
        j = self.graph.num_nodes
        leaves = jax.tree_util.tree_leaves(theta0)
        assert all(l.shape[0] == j for l in leaves), (
            f"every leaf must have leading node axis {j}")
        zeros = jax.tree_util.tree_map(jnp.zeros_like, theta0)
        adj = jnp.asarray(self.graph.adj)
        bar = res_lib.neighbor_mean(theta0, adj)
        return ConsensusState(
            theta=theta0, lam=zeros, theta_bar=bar,
            penalty=init_penalty_state(self.penalty_cfg, j),
            t=jnp.zeros((), jnp.int32),
            topo=(None if self._topo_rt is None
                  else self._topo_rt.init_state()))

    # -- inner solvers ----------------------------------------------------------
    def _solve_gradient(self, data, theta, lam, eta, adj):
        """Vmapped K-step gradient descent on the augmented objective."""
        adj_f = adj.astype(jnp.float32)
        w = eta * adj_f                       # [J, J]
        wsum = w.sum(axis=1)                  # [J]

        # Precompute the eta-weighted neighbor pull:
        #   sum_j eta_ij (theta_i^t + theta_j^t)/2   (constant during solve)
        def pull_leaf(leaf):
            flat = leaf.reshape(leaf.shape[0], -1)
            nbr = w @ flat                                  # sum_j eta_ij th_j
            own = wsum[:, None] * flat                      # sum_j eta_ij th_i
            return (0.5 * (nbr + own)).reshape(leaf.shape)

        pull = jax.tree_util.tree_map(pull_leaf, theta)

        def one_node(data_i, th0, lam_i, pull_i, wsum_i):
            def aug(th):
                lin = sum(jnp.vdot(a, b).real for a, b in zip(
                    jax.tree_util.tree_leaves(lam_i),
                    jax.tree_util.tree_leaves(th)))
                # sum_j eta ||th - mid||^2
                #   = wsum ||th||^2 - 2 <th, pull> + const
                quad = 0.0
                for th_l, p_l in zip(jax.tree_util.tree_leaves(th),
                                     jax.tree_util.tree_leaves(pull_i)):
                    quad = quad + wsum_i * jnp.sum(jnp.square(th_l)) \
                        - 2.0 * jnp.sum(th_l * p_l)
                return self.objective(data_i, th) + 2.0 * lin + quad

            g = jax.grad(aug)

            def step(th, _):
                gr = g(th)
                # steepest descent with exact line search along -g via an
                # hvp:  step* = <g,g> / <g, H g>  — exact for quadratic
                # augmented objectives, parameter-free, topology-robust.
                _, hg = jax.jvp(g, (th,), (gr,))
                gg = sum(jnp.vdot(a, a).real
                         for a in jax.tree_util.tree_leaves(gr))
                ghg = sum(jnp.vdot(a, b).real for a, b in zip(
                    jax.tree_util.tree_leaves(gr),
                    jax.tree_util.tree_leaves(hg)))
                # the quadratic consensus term guarantees curvature
                # >= 2*wsum; fall back to it if f_i is locally concave.
                safe = jnp.maximum(ghg, 2.0 * wsum_i * gg + 1e-12)
                lr = self.inner_lr * gg / (safe + 1e-30)
                return jax.tree_util.tree_map(
                    lambda a, b: a - lr * b, th, gr), None

            th, _ = jax.lax.scan(step, th0, None, length=self.inner_steps)
            return th

        return jax.vmap(one_node)(data, theta, lam, pull, wsum)

    # -- churn -----------------------------------------------------------------
    def apply_churn(self, state: ConsensusState, victim: int
                    ) -> ConsensusState:
        """Host-side layout-preserving node drop (mirrors the trainer's).

        Ghosts the victim in the topology state — all shapes survive, the
        jitted step keeps its cache, and the runtime rewires survivors and
        asserts connectivity. Requires ``topology_cfg``.
        """
        if self._topo_rt is None:
            raise ValueError("node churn needs a topology_cfg")
        return state._replace(topo=self._topo_rt.drop_node(state.topo,
                                                           victim))

    # -- one outer iteration ----------------------------------------------------
    @partial(jax.jit, static_argnums=0)
    def step(self, state: ConsensusState, data: PyTree) -> tuple[
            ConsensusState, dict]:
        """data: pytree with leading node axis [J, ...] (local observations)."""
        g = self.graph
        adj_static = jnp.asarray(g.adj)
        # dynamic topology: the traced mask IS the adjacency this round
        adj = state.topo.mask if state.topo is not None else adj_static
        eta = state.penalty.eta
        # degree compensation applies where eta is CONSUMED — the penalty
        # schedule itself keeps adapting the raw eta around eta0
        eta_eff = eta * self._edge_scale

        # (1) local argmin
        if self.local_solver is not None:
            theta_new = self.local_solver(data, state.theta, state.lam,
                                          eta_eff, adj)
        else:
            theta_new = self._solve_gradient(data, state.theta, state.lam,
                                             eta_eff, adj)

        # (2)+(3) neighbor exchange and dual update:
        #   lam_i += 1/2 sum_j eta_ij (theta_i - theta_j)
        # using the SYMMETRIZED penalty — directed eta would break the
        # sum_i lam_i = 0 invariant and bias the fixed point (DESIGN.md §7).
        w = 0.5 * (eta_eff + eta_eff.T) * adj.astype(eta.dtype)
        wsum = w.sum(axis=1)

        def dual_leaf(lam_leaf, th_leaf):
            flat = th_leaf.reshape(th_leaf.shape[0], -1)
            diff = wsum[:, None] * flat - w @ flat
            return lam_leaf + 0.5 * diff.reshape(th_leaf.shape).astype(
                lam_leaf.dtype)

        lam_new = jax.tree_util.tree_map(dual_leaf, state.lam, theta_new)

        # (eq. 5) local residuals — with the APPLIED (scaled) penalties
        eta_node = res_lib.node_eta(eta_eff, adj)
        rr = res_lib.local_residuals(theta_new, state.theta_bar, adj, eta_node)

        # objective probes for AP/NAP-family schedules
        pcfg = self.penalty_cfg
        if pcfg.uses_objective_probes:
            f_self = jax.vmap(self.objective)(data, theta_new)

            def probe(i_data, th_i, th_all):
                def at_j(th_j):
                    pt = jax.tree_util.tree_map(
                        lambda a, b: 0.5 * (a + b), th_i, th_j) \
                        if self.probe_midpoint else th_j
                    return self.objective(i_data, pt)
                return jax.vmap(at_j)(th_all)

            f_nbr = jax.vmap(probe, in_axes=(0, 0, None))(
                data, theta_new, theta_new)
        else:
            f_self = jax.vmap(self.objective)(data, theta_new)
            f_nbr = None

        if state.topo is not None:
            # gated GRAPH edges keep adapting (the eq. 10 top-up must see
            # them to revive); ghost rows/cols never do
            alive = state.topo.node_alive
            adj_pen = (adj_static & alive[:, None] & alive[None, :]) | adj
        else:
            adj_pen = adj_static
        penalty_new = update_penalty(
            pcfg, state.penalty, adj=adj_pen, f_self=f_self, f_nbr=f_nbr,
            r_norm=rr.r_norm, s_norm=rr.s_norm)

        topo_new = state.topo
        if state.topo is not None:
            topo_new = self._topo_rt.update(state.topo, penalty=penalty_new,
                                            r_norm=rr.r_norm)
            # zero-kick gating: absorb each newly-gated edge's final
            # consensus force into the dual (one extra dual-ascent step
            # restricted to those edges), so removing the edge leaves every
            # node's augmented stationarity EXACTLY unchanged at the
            # current iterate — gating never perturbs a converged region.
            # Antisymmetric per edge pair, so sum_i lam_i = 0 survives.
            newly_off = (state.topo.mask & ~topo_new.mask).astype(w.dtype)
            w_off = w * newly_off
            woff_sum = w_off.sum(axis=1)

            def absorb_leaf(lam_leaf, th_leaf):
                flat = th_leaf.reshape(th_leaf.shape[0], -1)
                diff = woff_sum[:, None] * flat - w_off @ flat
                return lam_leaf + 0.5 * diff.reshape(th_leaf.shape).astype(
                    lam_leaf.dtype)

            lam_new = jax.tree_util.tree_map(absorb_leaf, lam_new, theta_new)
        new_state = ConsensusState(theta=theta_new, lam=lam_new,
                                   theta_bar=rr.theta_bar,
                                   penalty=penalty_new, t=state.t + 1,
                                   topo=topo_new)
        metrics = {
            "objective": f_self.sum(),
            "r_norm": rr.r_norm,
            "s_norm": rr.s_norm,
            "eta_mean": res_lib.node_eta(penalty_new.eta, adj).mean(),
            "eta_min": jnp.where(adj, penalty_new.eta, jnp.inf).min(),
            "eta_max": jnp.where(adj, penalty_new.eta, -jnp.inf).max(),
        }
        if state.topo is not None:
            from repro.topology import active_edge_fraction
            metrics["active_edges"] = active_edge_fraction(state.topo,
                                                           adj_static)
        return new_state, metrics

    # -- convergence-driven run -------------------------------------------------
    def run(self, state: ConsensusState, data: PyTree, *, max_iters: int,
            rel_tol: float = 1e-3) -> tuple[ConsensusState, dict]:
        """Python-loop driver with the paper's relative-change criterion (§5).

        Returns final state and a history dict (objective trace, iters).
        """
        hist = {"objective": [], "r_norm": [], "eta_mean": []}
        prev_obj = None
        iters = max_iters
        for it in range(max_iters):
            state, m = self.step(state, data)
            obj = float(m["objective"])
            hist["objective"].append(obj)
            hist["r_norm"].append(float(jnp.max(m["r_norm"])))
            hist["eta_mean"].append(float(m["eta_mean"]))
            if prev_obj is not None:
                rel = abs(obj - prev_obj) / (abs(prev_obj) + 1e-12)
                if rel < rel_tol:
                    iters = it + 1
                    break
            prev_obj = obj
        hist["iterations"] = iters
        return state, hist


def consensus_error(theta: PyTree) -> jax.Array:
    """Max pairwise L2 disagreement across nodes — a convergence diagnostic."""
    errs = []
    for leaf in jax.tree_util.tree_leaves(theta):
        flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
        mean = flat.mean(axis=0, keepdims=True)
        errs.append(jnp.linalg.norm(flat - mean, axis=1).max())
    return jnp.stack(errs).max()
