"""Traced dynamic-topology state for the consensus engine.

The paper's §4 observation — budget-gated penalty adaptation "effectively
leads to an adaptive, dynamic network topology" — is promoted here to a
first-class, *traced* runtime object. ``TopologyState`` carries a per-edge
active mask (``[J, J]``, like ``PenaltyState``) plus per-edge epoch counters
and node-liveness, so edges can drop, revive and rewire between ADMM rounds
without recompiling anything: the compiled step consumes the mask as data.

Composition of the mask (all [J, J] bool, symmetric, zero diagonal):

    mask = (pattern & adj  |  backbone  |  repair) & alive_i & alive_j

  * ``pattern``  — what the scheduler decided this epoch (see
    ``topology.schedulers``);
  * ``backbone`` — a static spanning subgraph that is never gated, the
    connectivity guarantee (stored on the state so churn can rewrite it);
  * ``repair``   — extra edges activated by the churn runtime when a node
    loss breaks the backbone (see ``topology.runtime``);
  * ``node_alive`` — row/col liveness; a dead pod's edges are all inactive
    ("ghost row": the layout keeps shape [J, ...], only the mask changes).

Epoch counters increment whenever an edge flips active<->inactive — they
are the per-edge analogue of ``PenaltyState.n_incr`` and feed monitoring
(how often does the scheduler churn this edge?).

The async executor (``repro.async_exec``) extends the state with two more
per-edge arrays:

  * ``age``  — the staleness clock: ``age[i, j]`` counts consensus rounds
    since node i last consumed a FRESH wire payload from node j (0 =
    consumed this round). The sync engine never ticks it, so it stays zero
    on the synchronous path; the ``stale`` scheduler and the executor's
    in-round gating both read it.
  * ``kick`` — pending zero-kick weights: when the scheduler gates an edge
    at the END of round t, the fused engine can only absorb that edge's
    final consensus force into the dual at round t+1 (its neighbor's
    parameters arrive on the wire then). ``kick[i, j]`` carries the
    symmetrized penalty weight of each newly-gated edge across the round
    boundary; the kernel consumes and clears it next round.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class TopologyState(NamedTuple):
    """Traced per-edge topology state. All [J, J] except node_alive [J]."""

    mask: jax.Array        # [J, J] bool — edges active for the NEXT round
    backbone: jax.Array    # [J, J] bool — never-gated spanning subgraph
    repair: jax.Array      # [J, J] bool — churn-activated rewiring edges
    node_alive: jax.Array  # [J]    bool — pod liveness (ghost rows when False)
    epoch: jax.Array       # [J, J] int32 — per-edge flip counters
    key: jax.Array         # PRNG key (random scheduler)
    t: jax.Array           # []     int32 epoch counter
    age: jax.Array         # [J, J] int32 — staleness clocks (async executor)
    kick: jax.Array        # [J, J] f32 — pending zero-kick weights


def init_topology_state(adj: np.ndarray, backbone: np.ndarray,
                        *, seed: int = 0) -> TopologyState:
    """Fresh state: every graph edge active, everyone alive, epoch zero."""
    adj = np.asarray(adj, dtype=bool)
    j = adj.shape[0]
    return TopologyState(
        mask=jnp.asarray(adj),
        backbone=jnp.asarray(np.asarray(backbone, dtype=bool)),
        repair=jnp.zeros((j, j), bool),
        node_alive=jnp.ones((j,), bool),
        epoch=jnp.zeros((j, j), jnp.int32),
        key=jax.random.PRNGKey(seed),
        t=jnp.zeros((), jnp.int32),
        age=jnp.zeros((j, j), jnp.int32),
        kick=jnp.zeros((j, j), jnp.float32))


def compose_mask(pattern: jax.Array, state: TopologyState,
                 adj: jax.Array) -> jax.Array:
    """Apply the mask composition rule (module docstring) to a pattern."""
    alive = state.node_alive
    m = (pattern & adj) | (state.backbone | state.repair)
    return m & alive[:, None] & alive[None, :]


def advance(state: TopologyState, new_mask: jax.Array,
            key: jax.Array | None = None) -> TopologyState:
    """Install a new mask, bumping per-edge epochs where edges flipped."""
    flipped = (new_mask != state.mask).astype(jnp.int32)
    return state._replace(mask=new_mask, epoch=state.epoch + flipped,
                          key=state.key if key is None else key,
                          t=state.t + 1)


def tick_age(state: TopologyState, fresh: jax.Array) -> TopologyState:
    """Advance the staleness clocks: reset where ``fresh`` [J, J], else +1.

    Only the async executor calls this (once per consensus round) — on the
    synchronous path every payload is fresh every round and ``age`` stays
    identically zero.
    """
    age = jnp.where(fresh, 0, state.age + 1).astype(jnp.int32)
    return state._replace(age=age)


def sym_age(state: TopologyState) -> jax.Array:
    """[J, J] int32 — symmetrized staleness: max over both directions.

    ``age[i, j]`` and ``age[j, i]`` generally differ (i and j consume each
    other's payloads at different times). Weighting consensus by the max
    keeps the applied penalties symmetric, which preserves the
    ``sum_i lam_i = 0`` dual invariant (see ``core.admm`` docstring) at the
    cost of piggy-backing one int per edge on the wire in a real
    deployment (the simulation's replicated state gets it for free).
    """
    return jnp.maximum(state.age, state.age.T)


def active_degree(state: TopologyState) -> jax.Array:
    """[J] float32 — number of active edges per node."""
    return state.mask.astype(jnp.float32).sum(axis=1)


def active_edge_fraction(state: TopologyState, adj: jax.Array) -> jax.Array:
    """Scalar — active edges as a fraction of the static graph's edges."""
    adj_n = jnp.maximum(adj.astype(jnp.float32).sum(), 1.0)
    return state.mask.astype(jnp.float32).sum() / adj_n
