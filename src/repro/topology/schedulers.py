"""Pluggable edge-gating schedulers for the dynamic-topology runtime.

A scheduler is a pure, traced function deciding which graph edges take part
in the NEXT consensus round. It sees the penalty state (for the paper's §4
budget semantics), the local residuals, and the epoch counter — and returns
a [J, J] bool *pattern* that ``topology.state.compose_mask`` combines with
the never-gated backbone, churn repairs and node liveness. Every scheduler
is recompilation-free: the decision is data, not program.

Schedulers:

  * ``static``      — the full graph every epoch (PR 1 behavior, default).
  * ``budget``      — paper §4 made literal: an edge deactivates once its
                      NAP budget is exhausted (cum_tau >= T_ij in BOTH
                      directions) and both endpoints sit below the consensus
                      tolerance; a budget top-up (eq. 10) revives it.
  * ``random``      — Iutzeler-style Bernoulli edge activation with keep
                      probability ``activation_p``, redrawn every ``period``
                      epochs (deterministic per epoch via fold_in).
  * ``round_robin`` — rotates through the graph's permutation rounds (edge
                      coloring): each epoch activates one matching, so every
                      node talks to at most one peer per direction.
  * ``stale``       — bounded-staleness gating for the async executor: an
                      edge deactivates while either endpoint's wire payload
                      is older than ``max_staleness`` rounds (the
                      ``TopologyState.age`` clocks) and revives the moment a
                      fresh payload lands. On the synchronous path ages stay
                      zero, so ``stale`` degenerates to ``static``.

Connectivity: no scheduler is trusted to keep the masked graph connected on
its own — the backbone does that by construction (see ``topology.state``).
(For ``stale`` this means a persistently slow neighbor's BACKBONE edge stays
active in the mask; the async executor's in-round weight gating still zeroes
its math until a payload arrives — a transient, self-healing disconnection,
unlike scheduler gating which must preserve connectivity forever.)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.penalty import PenaltyState, budget_exhausted
from repro.topology.state import (TopologyState, advance, compose_mask,
                                  sym_age)

SCHEDULERS = ("static", "budget", "random", "round_robin", "stale")


@dataclasses.dataclass(frozen=True)
class TopologyConfig:
    """Dynamic-topology knobs.

    Attributes:
      scheduler: one of ``SCHEDULERS``. ``static`` + ``churn=False`` (the
        default) keeps the engine on the exact PR 1 code path.
      churn: enable layout-preserving node churn — the engine compiles
        against the offset *superset* (graph offsets + ``spare_offsets``)
        and a lost pod becomes a masked ghost row instead of a crash.
      gate_tol: ``budget`` — an edge may only deactivate once both
        endpoints' primal residual norms are below this. Set it WELL below
        (~100x) the residual level you run to: a gated edge's remaining
        disagreement can only decay through the sparser surviving graph,
        so gating above your target accuracy trades iterations for wire.
      activation_p: ``random`` — per-edge Bernoulli keep probability.
      period: epochs between redraws (``random``) / rotations
        (``round_robin``).
      spare_offsets: extra circulant offsets compiled into the engine's
        exchange superset for churn repair; () = auto ((2, J-2) when churn
        is on and the graph doesn't already include them).
      skip_dead_offsets: engine only — wrap each offset's exchange in a
        ``lax.cond`` so a fully-gated offset round skips its
        collective-permute and probe at runtime (the mask is replicated, so
        every device takes the same branch).
      max_staleness: ``stale`` — edges whose symmetrized payload age
        exceeds this many rounds deactivate until a fresh payload arrives.
      seed: PRNG seed for the ``random`` scheduler.
    """

    scheduler: str = "static"
    churn: bool = False
    gate_tol: float = 1e-4
    activation_p: float = 0.5
    period: int = 1
    spare_offsets: tuple = ()
    skip_dead_offsets: bool = True
    max_staleness: int = 1
    seed: int = 0

    def __post_init__(self):
        if self.scheduler not in SCHEDULERS:
            raise ValueError(
                f"scheduler {self.scheduler!r} not in {SCHEDULERS}")
        if not 0.0 < self.activation_p <= 1.0:
            raise ValueError(f"activation_p {self.activation_p} not in (0,1]")
        if self.period < 1:
            raise ValueError(f"period {self.period} < 1")

    @property
    def is_dynamic(self) -> bool:
        """Whether the engine needs the masked (non-PR-1) code path."""
        return self.scheduler != "static" or self.churn

    @property
    def can_gate(self) -> bool:
        """Whether the scheduler can flip a graph edge off mid-run.

        Gating engines compile the zero-kick absorption term into the fused
        kernel; ``static`` (even with churn — a crashed node's last payload
        is not trusted for absorption) keeps the kick-free kernel and stays
        bit-identical to the PR 1 round.
        """
        return self.scheduler != "static"

    def validate_penalty(self, penalty_cfg) -> None:
        """Reject scheduler/penalty pairings that silently do nothing."""
        if self.scheduler == "budget" and not penalty_cfg.uses_budget:
            raise ValueError(
                f"budget topology scheduler needs a budget-spending penalty "
                f"scheme (nap/vp_nap), got {penalty_cfg.scheme!r} — its "
                f"gate would never fire and the mask would stay static")


def budget_gate(penalty: PenaltyState, r_norm: jax.Array,
                gate_tol: float,
                prev_off: jax.Array | None = None) -> jax.Array:
    """[J, J] bool — edges the §4 budget semantics says may deactivate.

    True where BOTH directed budgets are exhausted (cum_tau >= T_ij, the
    eq. 9 gate that freezes adaptation) AND both endpoints' local primal
    residuals are below ``gate_tol`` (the edge has done its consensus job).

    ``prev_off`` (edges gated last epoch) latches the gate: a gated edge
    stays gated while exhausted even if residuals drift back up — revival
    happens ONLY through a budget top-up (eq. 10), which raises T_ij above
    cum_tau and flips ``exhausted`` off. Without the latch the gate flaps
    around the tolerance (gate -> drift -> revive -> re-converge -> gate).
    """
    exhausted = budget_exhausted(penalty)
    exhausted = exhausted & exhausted.T
    close = r_norm < gate_tol
    gate = close[:, None] & close[None, :]
    if prev_off is not None:
        gate = gate | prev_off
    return exhausted & gate


def update_topology(cfg: TopologyConfig, state: TopologyState, *,
                    adj: jax.Array,
                    penalty: PenaltyState | None = None,
                    r_norm: jax.Array | None = None,
                    rotation: jax.Array | None = None) -> TopologyState:
    """One scheduler epoch: decide the pattern, compose, advance counters.

    Args:
      adj: [J, J] bool — the static graph adjacency (constant under jit).
      penalty / r_norm: required for ``budget``.
      rotation: [R, J, J] bool stack of rotation patterns, required for
        ``round_robin`` (precomputed by ``TopologyRuntime``).
    """
    adj = adj.astype(bool)

    if cfg.scheduler == "static":
        pattern = adj

    elif cfg.scheduler == "budget":
        assert penalty is not None and r_norm is not None, cfg.scheduler
        prev_off = adj & ~state.mask       # backbone edges never appear here
        pattern = adj & ~budget_gate(penalty, r_norm.astype(jnp.float32),
                                     cfg.gate_tol, prev_off)

    elif cfg.scheduler == "random":
        # deterministic per-epoch draw: same key within a period
        key = jax.random.fold_in(state.key, state.t // cfg.period)
        j = adj.shape[0]
        u = jax.random.uniform(key, (j, j))
        u = jnp.triu(u, 1)
        keep = (u + u.T) < cfg.activation_p        # symmetric by build
        pattern = adj & keep

    elif cfg.scheduler == "round_robin":
        assert rotation is not None, "round_robin needs rotation masks"
        phase = (state.t // cfg.period) % rotation.shape[0]
        pattern = adj & rotation[phase]

    elif cfg.scheduler == "stale":
        # bounded staleness: gate while either direction's payload is older
        # than the bound; a fresh arrival (age reset by tick_age) revives
        # the edge the same epoch — no latch, staleness is self-healing
        pattern = adj & (sym_age(state) <= cfg.max_staleness)

    else:  # pragma: no cover
        raise AssertionError(cfg.scheduler)

    return advance(state, compose_mask(pattern, state, adj))
