"""Host-side orchestration for the dynamic-topology subsystem.

``TopologyRuntime`` owns everything that is *static at trace time* but too
graph-specific for the schedulers: the spanning backbone (the connectivity
guarantee), the round-robin rotation masks, the circulant offset superset
the fused engine compiles against, and the churn repair logic that turns a
lost pod into a topology epoch instead of a crash.

Churn model (layout-preserving): the compiled step functions keep their
[J, ...] shapes forever. Losing node v flips ``node_alive[v]`` off, masks
all its edges, and — when that breaks the backbone — activates *repair*
edges drawn from the edge universe (for the fused engine: the circulant
offset superset, which is why ``spare_offsets`` exist; for the dense
reproduction path: any node pair). The surviving subgraph is re-asserted
connected on the host before the new mask ships to the devices. No shapes
change, so nothing recompiles.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.graph import Graph, connected_components
from repro.core.penalty import PenaltyState
from repro.topology.schedulers import TopologyConfig, update_topology
from repro.topology.state import TopologyState, init_topology_state


def spanning_backbone(g: Graph) -> np.ndarray:
    """[J, J] bool — a minimal never-gated spanning subgraph of ``g``.

    Circulant graphs whose offset set contains the unit offset get the
    offset-1 ring (stays inside the engine's permute schedule); anything
    else gets a BFS spanning tree.
    """
    j = g.num_nodes
    bb = np.zeros((j, j), dtype=bool)
    if j <= 1:
        return bb
    ring_ok = all(g.adj[i, (i + 1) % j] for i in range(j))
    if ring_ok and j > 2:
        for i in range(j):
            bb[i, (i + 1) % j] = bb[(i + 1) % j, i] = True
        return bb
    # BFS tree from node 0
    seen = {0}
    frontier = [0]
    while frontier:
        i = frontier.pop(0)
        for nb in g.neighbors(i):
            nb = int(nb)
            if nb not in seen:
                seen.add(nb)
                bb[i, nb] = bb[nb, i] = True
                frontier.append(nb)
    return bb


def rotation_masks(g: Graph) -> np.ndarray:
    """[R, J, J] bool — one symmetric mask per permutation round.

    Built from ``Graph.permutation_rounds()`` (greedy edge coloring): each
    round is a partial matching, so the ``round_robin`` scheduler activates
    at most one peer per node per direction per epoch.
    """
    j = g.num_nodes
    rounds = g.permutation_rounds()
    if not rounds:
        return np.zeros((1, j, j), dtype=bool)
    masks = np.zeros((len(rounds), j, j), dtype=bool)
    for r, pairs in enumerate(rounds):
        for (a, b) in pairs:
            masks[r, a, b] = masks[r, b, a] = True
    return masks


def _components(adj: np.ndarray, alive: np.ndarray) -> list[list[int]]:
    """Connected components of the alive-induced subgraph."""
    masked = np.asarray(adj, bool) & alive[:, None] & alive[None, :]
    return [c for c in connected_components(masked) if alive[c[0]]]


class TopologyRuntime:
    """Builds and advances ``TopologyState`` for one graph + config.

    ``update`` is traced (call it inside the jitted consensus step);
    ``init_state`` and ``drop_node`` are host-side.
    """

    def __init__(self, graph: Graph, cfg: TopologyConfig, *,
                 edge_universe: np.ndarray | None = None):
        self.graph = graph
        self.cfg = cfg
        self.backbone = spanning_backbone(graph)
        self.rotation = rotation_masks(graph)
        j = graph.num_nodes
        self.offsets = self._offset_superset()
        if edge_universe is not None:
            self.edge_universe = np.asarray(edge_universe, dtype=bool)
        elif self.offsets:                       # engine: circulant superset
            u = np.zeros((j, j), dtype=bool)
            for off in self.offsets:
                for i in range(j):
                    u[i, (i + off) % j] = True
            np.fill_diagonal(u, False)
            self.edge_universe = u | u.T
        else:                                    # dense path: any pair
            self.edge_universe = ~np.eye(j, dtype=bool)

    # ------------------------------------------------------------ static ----
    def _offset_superset(self) -> list[int]:
        """Graph circulant offsets + churn spares (engine permute schedule)."""
        j = self.graph.num_nodes
        if j <= 1:
            return []
        offs = set(self.graph.neighbor_offsets_ring())
        if self.cfg.churn:
            spares = self.cfg.spare_offsets or (2, j - 2)
            offs |= {o % j for o in spares if 0 < o % j < j}
        return sorted(offs)

    def expected_active_fraction(self) -> float:
        """Static estimate of |mask| / |adj| for edge-level accounting.

        budget's steady state is its lower bound (only the backbone left);
        random mixes the Bernoulli keep-rate with the backbone floor;
        round_robin averages its rotation phases exactly.
        """
        adj_n = max(int(self.graph.adj.sum()), 1)
        bb_frac = self.backbone.sum() / adj_n
        cfg = self.cfg
        if cfg.scheduler in ("static", "stale"):
            # stale gates only while payloads age out — zero edges in the
            # no-straggler steady state, so the static estimate is its bound
            return 1.0
        if cfg.scheduler == "budget":
            return float(bb_frac)
        if cfg.scheduler == "random":
            p = cfg.activation_p
            return float(p + (1.0 - p) * bb_frac)
        per_phase = [((m | self.backbone) & self.graph.adj).sum()
                     for m in self.rotation]
        return float(np.mean(per_phase) / adj_n)

    def expected_active_offsets(self) -> float:
        """Expected superset offsets that PERMUTE per round (wire units).

        The engine skips an offset's collective-permute only when the
        entire offset round is dead, so wire volume is per-offset
        all-or-nothing — a partially gated offset still moves the full
        buffer. Steady-state patterns per scheduler: static/random keep
        every graph-edge offset alive (a Bernoulli draw almost surely
        leaves one edge per offset at useful J), budget decays to the
        backbone, round_robin averages its phases.
        """
        j = self.graph.num_nodes
        if j <= 1 or not self.offsets:
            return 0.0
        cfg = self.cfg
        if cfg.scheduler == "budget":
            patterns = [self.backbone]
        elif cfg.scheduler == "round_robin":
            patterns = [m | self.backbone for m in self.rotation]
        else:                                   # static, random
            patterns = [self.graph.adj]
        idx = np.arange(j)

        def alive_offsets(pattern):
            return sum(1 for off in self.offsets
                       if pattern[idx, (idx + off) % j].any())

        return float(np.mean([alive_offsets(p) for p in patterns]))

    # ------------------------------------------------------------- state ----
    def init_state(self) -> TopologyState:
        return init_topology_state(self.graph.adj, self.backbone,
                                   seed=self.cfg.seed)

    def update(self, state: TopologyState, *,
               penalty: PenaltyState | None = None,
               r_norm=None) -> TopologyState:
        """One traced scheduler epoch (constants closed over)."""
        return update_topology(
            self.cfg, state, adj=jnp.asarray(self.graph.adj),
            penalty=penalty, r_norm=r_norm,
            rotation=jnp.asarray(self.rotation))

    # ------------------------------------------------------------- churn ----
    def drop_node(self, state: TopologyState, victim: int) -> TopologyState:
        """Host-side layout-preserving node drop -> new TopologyState.

        Ghosts the victim (liveness off, all its edges masked), then — if
        the backbone no longer spans the survivors — activates repair edges
        from the edge universe, preferring the victim's former neighbors
        (the cheapest rewiring that preserves locality). Asserts the
        surviving subgraph is connected before shipping the new mask.
        """
        j = self.graph.num_nodes
        if not 0 <= victim < j:
            raise ValueError(f"victim {victim} out of range [0, {j})")
        alive = np.asarray(state.node_alive).copy()
        if not alive[victim]:
            return state
        alive[victim] = False
        alive2 = alive[:, None] & alive[None, :]
        backbone = np.asarray(state.backbone) & alive2
        repair = np.asarray(state.repair) & alive2
        core = backbone | repair
        comps = _components(core, alive)
        if len(comps) > 1:
            repair = repair | self._bridge(comps, victim, alive)
            core = backbone | repair
            comps = _components(core, alive)
        if alive.sum() > 1 and len(comps) != 1:
            raise RuntimeError(
                f"edge universe cannot reconnect survivors after dropping "
                f"node {victim} (components: {comps}); widen spare_offsets")
        mask = (np.asarray(state.mask) & alive2) | core
        flipped = (mask != np.asarray(state.mask)).astype(np.int32)
        # the ghost's staleness clocks and pending kicks die with it: its
        # last payload is not trusted for absorption (it may be mid-crash
        # garbage in a real deployment), so churn gating is kick-free
        new = state._replace(
            mask=jnp.asarray(mask), backbone=jnp.asarray(backbone),
            repair=jnp.asarray(repair), node_alive=jnp.asarray(alive),
            epoch=state.epoch + jnp.asarray(flipped),
            age=state.age * jnp.asarray(alive2, jnp.int32),
            kick=state.kick * jnp.asarray(alive2, jnp.float32))
        # keep the old leaves' (committed, replicated) shardings — a bare
        # host array would change jitted consumers' cache key and force a
        # recompile, defeating the point of the layout-preserving drop
        import jax

        def _like(n, o):
            return jax.device_put(n, o.sharding) if hasattr(o, "sharding") \
                else n

        return jax.tree_util.tree_map(_like, new, state)

    def _bridge(self, comps: list[list[int]], victim: int,
                alive: np.ndarray) -> np.ndarray:
        """Spanning chain over components through the edge universe.

        Greedy: repeatedly merge the first component with any other it can
        reach through a universe edge, preferring endpoints that were the
        victim's neighbors. Raises nothing here — the caller re-checks
        connectivity and reports unreachable components.
        """
        j = self.graph.adj.shape[0]
        nbrs = set(int(x) for x in self.graph.neighbors(victim))
        bridge = np.zeros((j, j), dtype=bool)
        comps = [list(c) for c in comps]
        merged = comps[0]
        rest = comps[1:]
        progress = True
        while rest and progress:
            progress = False
            for k, comp in enumerate(rest):
                pairs = [(a, b) for a in merged for b in comp
                         if self.edge_universe[a, b]]
                if not pairs:
                    continue
                pairs.sort(key=lambda ab: (ab[0] not in nbrs)
                           + (ab[1] not in nbrs))
                a, b = pairs[0]
                bridge[a, b] = bridge[b, a] = True
                merged = merged + comp
                rest.pop(k)
                progress = True
                break
        return bridge
