"""Dynamic-topology runtime: traced edge gating, churn and rewiring.

See ``docs/topology.md`` for the state machine and the scheduler contract.
"""
from repro.topology.schedulers import (SCHEDULERS, TopologyConfig,
                                       budget_gate, update_topology)
from repro.topology.state import (TopologyState, active_degree,
                                  active_edge_fraction, advance,
                                  compose_mask, init_topology_state,
                                  sym_age, tick_age)
from repro.topology.runtime import (TopologyRuntime, rotation_masks,
                                    spanning_backbone)

__all__ = [
    "SCHEDULERS", "TopologyConfig", "budget_gate", "update_topology",
    "TopologyState", "active_degree", "active_edge_fraction", "advance",
    "compose_mask", "init_topology_state", "sym_age", "tick_age",
    "TopologyRuntime", "rotation_masks", "spanning_backbone",
]
