"""Sharding rules: logical axes -> mesh axes, activation constraints.

Mesh axes (launch/mesh.py):
  * single-pod:  ("data", "model")            = (16, 16)
  * multi-pod:   ("pod", "data", "model")     = (2, 16, 16)

Logical rules (MaxText-style):
  batch       -> ("pod", "data")     activations' leading batch dim
  vocab       -> "model"             embedding/unembedding vocab dim
  heads       -> "model"             attention heads (TP)
  kv_heads    -> "model" if divisible else None (replicate small-GQA KV)
  mlp         -> "model"             d_ff / expert-ff dim (TP)
  experts     -> "model"             MoE expert dim (EP)
  fsdp        -> "data"              parameter FSDP shard dim (embed/d_model)
  seq         -> "model"             sequence parallelism (long-context)

The mesh is installed via ``use_mesh`` (a contextvar), so model code can call
``shard(x, *logical_axes)`` without threading mesh handles everywhere; with no
installed mesh the call is a no-op (CPU smoke tests see 1 device).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: contextvars.ContextVar[Mesh | None] = contextvars.ContextVar(
    "repro_mesh", default=None)
_RULES: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "repro_rules", default=None)


def default_rules(mesh: Mesh, *, kv_divisible: bool = True,
                  heads_divisible: bool = True,
                  seq_sharded: bool = False) -> dict[str, Any]:
    has_pod = "pod" in mesh.axis_names
    batch = ("pod", "data") if has_pod else ("data",)
    return {
        "batch": batch,
        "vocab": "model",
        "heads": "model" if heads_divisible else None,
        "kv_heads": "model" if (kv_divisible and heads_divisible) else None,
        "mlp": "model",
        "experts": "model",
        "fsdp": "data",
        "seq": "model" if seq_sharded else None,
        "none": None,
    }


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: dict | None = None):
    tok1 = _MESH.set(mesh)
    tok2 = _RULES.set(rules if rules is not None else
                      (default_rules(mesh) if mesh is not None else None))
    try:
        yield
    finally:
        _MESH.reset(tok1)
        _RULES.reset(tok2)


def current_mesh() -> Mesh | None:
    return _MESH.get()


def abstract_mesh():
    """``jax.sharding.get_abstract_mesh()`` where it exists (newer jax);
    None on 0.4.x, where there is no ambient abstract-mesh context."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    return getter() if getter is not None else None


def axis_size(name: str) -> int:
    """``jax.lax.axis_size`` across jax versions (0.4.x: psum of the literal
    1 over the axis, which constant-folds to a static int)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def inpod_axes(mesh: Mesh | None) -> tuple[tuple[str, ...], int]:
    """Non-'pod' mesh axes and their total device count.

    The consensus engine's in-pod shard grid: ``ConsensusTrainer`` and the
    dry-run roofline both derive ``n_shards`` from this ONE helper so the
    accounting can never disagree with the engine. Returns ``((), 1)``
    when there is no mesh or no pod axis (nothing to shard over).
    """
    if mesh is None or "pod" not in mesh.axis_names:
        return (), 1
    axes = tuple(a for a in mesh.axis_names if a != "pod")
    size = 1
    for a in axes:
        size *= int(mesh.shape[a])
    return axes, size


def shard_map_compat(fn, mesh, *, in_specs, out_specs, manual_axes=None):
    """``shard_map`` across jax versions.

    ``manual_axes`` selects the mesh axes the region is manual over (all
    axes when None). Newer jax spells this ``jax.shard_map(...,
    axis_names=...)``; 0.4.x spells the complement
    ``jax.experimental.shard_map.shard_map(..., auto=...)``. Replication
    checking is disabled in both (regions here replicate over unmentioned
    in-pod axes on purpose).
    """
    if hasattr(jax, "shard_map"):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
        if manual_axes is not None:
            kw["axis_names"] = frozenset(manual_axes)
        return jax.shard_map(fn, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - frozenset(
        manual_axes if manual_axes is not None else mesh.axis_names)
    return _shard_map(fn, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, auto=auto)


def logical_to_spec(axes: Sequence[str | None]) -> P:
    """Map logical axis names to a PartitionSpec under the current rules."""
    rules = _RULES.get()
    if rules is None:
        return P(*([None] * len(axes)))
    out = []
    for ax in axes:
        if ax is None:
            out.append(None)
        else:
            out.append(rules.get(ax))
    return P(*out)


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain activation sharding by logical axis names (no-op w/o mesh)."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    spec = logical_to_spec(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*axes: str | None) -> NamedSharding | None:
    mesh = _MESH.get()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(axes))


def fit_spec(mesh: Mesh, shape: tuple[int, ...], spec: P) -> P:
    """Drop sharding on dims the axis size does not divide (e.g. batch=1)."""
    out = []
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if dim % size == 0 else None)
    return P(*out)


def spec_tree_to_shardings(mesh: Mesh, tree: Any) -> Any:
    """Convert a pytree of PartitionSpec into NamedShardings on `mesh`."""
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        tree, is_leaf=lambda s: isinstance(s, P))
