from repro.distributed.sharding import (current_mesh, default_rules,
                                        logical_to_spec, named_sharding,
                                        shard, spec_tree_to_shardings,
                                        use_mesh)

__all__ = ["current_mesh", "default_rules", "logical_to_spec",
           "named_sharding", "shard", "spec_tree_to_shardings", "use_mesh"]
