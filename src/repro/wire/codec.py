"""Wire codecs: how a packed flat buffer becomes bytes on the DCN.

The consensus engine's exchange moves ONE contiguous wire message per node
per graph offset (``docs/consensus_engine.md``). Historically the message
format was hard-coded in two places — ``FlatLayout.encode_int8`` (payload +
bitcast f32 scale tail) and ``ShardedLayout``'s per-shard variant — so each
new format forked the sync round, the async ``WireLedger`` and the dryrun
accounting. This module makes the format a pluggable **codec** behind the
transport (the same separation 1-bit-Adam / PowerSGD-style compression
stacks use, see PAPERS.md):

  * ``native``    — the packed buffer itself, in the params' common float
                    dtype (bf16 params = 2 B/param). Today's default.
  * ``int8``      — absmax per (node, leaf), f32 scales bitcast to an int8
                    tail. The pre-codec format, MOVED here verbatim:
                    payloads stay byte-identical (pinned by test).
  * ``fp8_e4m3``  — 1 B/param float8 (e4m3fn) payload with **per-block**
  * ``fp8_e5m2``    f32 scales aligned to the ``FlatLayout`` block grid,
                    so the fused kernel dequants each block from one SMEM
                    scalar indexed by its own program id — no block->leaf
                    table lookup, and on hardware with native fp8 the
                    dequant multiply is the only extra op.

A codec owns FOUR things (the interface every producer/consumer goes
through — trainer rounds, async ledger rows, dryrun roofline, benchmarks):

  * ``encode(buf)``          — [J, total] float -> [J, wire_width] message
  * ``decode(wire)``         — message -> (payload [J, total], scales|None)
  * ``wire_bytes()``         — bytes per node moved by one offset permute
  * ``kernel_dequant_spec()``— what the fused kernel needs to dequantize:
                               scale granularity (per-leaf vs per-block)
                               and the SMEM scale-row width.

Sharding: constructed with a ``ShardedLayout``, a codec emits the sharded
message — per-shard slabs, each self-contained (its own scale bytes), so a
device's ledger row decodes from local bytes only. Both quantized tails
split with the slabs: fp8 per-block scales shard exactly on the block
grid (zero redundancy), and the int8 per-leaf tail carries each slab's
local leaf window (``ShardedLayout.tail_gather`` — leaves spanning a slab
boundary repeat in the adjacent tails, everything else pays its 4 bytes
once), so sharded and unsharded wires move the same payload bytes.

All codecs are stateless views over a ``FlatLayout``; only buffer contents
are traced.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DequantSpec(NamedTuple):
    """What ``kernels.consensus_round`` needs to dequantize a wire payload.

    ``per_block=False``: scales are per (node, leaf) — the kernel resolves
    block b's scale through the block->leaf table (``scales[leaf_of[b]]``).
    ``per_block=True``: scales are per (node, block) on the layout's block
    grid — block b's scale is ``scales[b]`` directly (and under the sharded
    engine the scale rows shard with the slabs, so the local block id still
    indexes correctly).
    """

    per_block: bool
    scale_width: int            # trailing dim of the [J, scale_width] rows


class WireCodec:
    """Base codec: a stateless view over a layout (+ optional shard view)."""

    name = "?"

    def __init__(self, layout, slayout=None):
        self.layout = layout
        self.slayout = slayout          # flatten.ShardedLayout | None

    # ------------------------------------------------------------ sizes ----
    @property
    def wire_dtype(self):
        """Dtype of the wire message (what permutes move, ledgers hold)."""
        raise NotImplementedError

    @property
    def payload_dtype(self):
        """Dtype of the decoded payload fed to the fused kernel."""
        return self.wire_dtype

    @property
    def shard_wire_width(self) -> int:
        """Elements in ONE shard's self-contained message (sharded only)."""
        raise NotImplementedError

    @property
    def wire_width(self) -> int:
        """Elements in one node's whole wire message."""
        if self.slayout is not None:
            return self.slayout.n_shards * self.shard_wire_width
        return self._unsharded_width

    def wire_row_bytes(self) -> int:
        """Bytes of the per-DEVICE row one permute moves / a ledger row
        holds: one shard's message when sharded, the whole message else."""
        w = self.shard_wire_width if self.slayout is not None \
            else self._unsharded_width
        return w * jnp.dtype(self.wire_dtype).itemsize

    def wire_bytes(self) -> int:
        """Bytes per NODE moved by ONE graph-offset permute — the single
        source of truth for wire accounting (dryrun roofline, benchmarks,
        ledger sizing all read this)."""
        n = self.slayout.n_shards if self.slayout is not None else 1
        return n * self.wire_row_bytes()

    # -------------------------------------------------------- interface ----
    def encode(self, buf: jax.Array) -> jax.Array:
        raise NotImplementedError

    def decode(self, wire: jax.Array):
        raise NotImplementedError

    def kernel_dequant_spec(self) -> DequantSpec:
        raise NotImplementedError

    @property
    def scale_width(self) -> int:
        return self.kernel_dequant_spec().scale_width

    def unpack(self, payload: jax.Array, scales=None):
        """Decoded (payload, scales) -> dequantized parameter pytree (the
        probe path). Elementwise per leaf, so XLA fuses it into consumers."""
        spec = self.kernel_dequant_spec()
        return self.layout.unpack(payload, scales=scales,
                                  scales_per_block=spec.per_block)


class NativeCodec(WireCodec):
    """Uncompressed wire: the packed buffer in the params' float dtype."""

    name = "native"

    @property
    def wire_dtype(self):
        return self.layout.wire_dtype

    @property
    def _unsharded_width(self) -> int:
        return self.layout.total

    @property
    def shard_wire_width(self) -> int:
        return self.slayout.shard_total

    def encode(self, buf):
        return buf

    def decode(self, wire):
        return wire, None

    def kernel_dequant_spec(self):
        # scales are all-ones placeholders resolved per leaf — the exact
        # pre-codec shapes, keeping the native path bit-identical
        return DequantSpec(per_block=False,
                           scale_width=self.layout.num_leaves)


class Int8Codec(WireCodec):
    """Absmax int8 per (node, leaf), f32 scales bitcast to an in-band tail.

    This is the pre-codec wire format moved verbatim from
    ``optim.flatten`` (which now delegates here): the payload is
    absmax-quantized per (node, leaf); the f32 scales are bitcast to int8
    and appended, so the whole message is ONE contiguous int8 buffer — one
    collective-permute moves payload and scales together.

    Sharded: the quantized payload is IDENTICAL to the unsharded encode
    (max reductions are exact, so a cross-shard leaf quantizes the same
    bytes); only the scale tail's placement differs — bitcast and
    SHARD-LOCAL: each slab's tail carries only the scales of the leaves
    overlapping that slab (``ShardedLayout.tail_gather``), the same
    split-with-the-slabs discipline as the fp8 per-block tails, so the
    per-node wire pays the ~4*L scale bytes once, not once per shard.
    Every per-device slab stays self-contained: the bytes a device holds
    (or keeps in its wire-ledger row) suffice to dequantize its slab —
    what a per-device decoder / RDMA mailbox needs on real hardware.
    Apart from the per-leaf absmax (an in-pod max-reduce of the [J, L]
    scale row — leaves cross shard boundaries), every op is
    elementwise/reshape/static-gather on the slab grid, so under a
    ``P('pod', inner)`` sharding constraint each device quantizes and
    lays out only its slab.
    """

    name = "int8"

    @property
    def wire_dtype(self):
        return jnp.int8

    @property
    def _unsharded_width(self) -> int:
        return self.layout.total + 4 * self.layout.num_leaves

    @property
    def shard_wire_width(self) -> int:
        return self.slayout.shard_total + 4 * self.slayout.tail_leaves

    def encode(self, buf):
        lay = self.layout
        scales = lay.leaf_scales(buf)                      # [J, L]
        q = jnp.clip(jnp.round(buf / lay.scale_vector(scales)),
                     -127, 127).astype(jnp.int8)
        tail = jax.lax.bitcast_convert_type(scales, jnp.int8)  # [J, L, 4]
        j = q.shape[0]
        if self.slayout is None:
            return jnp.concatenate([q, tail.reshape(j, -1)], axis=1)
        s = self.slayout
        qr = q.reshape(j, s.n_shards, s.shard_total)
        # shard-local tails: slab s carries only ITS leaf window's scales
        # (static gather — spanning leaves repeat in adjacent tails)
        tails = tail[:, s.tail_gather, :].reshape(
            j, s.n_shards, 4 * s.tail_leaves)
        wire = jnp.concatenate([qr, tails], axis=2)
        return wire.reshape(j, s.n_shards * self.shard_wire_width)

    def decode(self, wire):
        """int8 wire -> (payload [J, total] int8, scales [J, L] f32).

        For an uncompressed (float) wire returns ``(wire, None)`` — the
        historical ``decode_split`` contract some callers rely on.
        Sharded: the payload peel is elementwise on the slab grid (each
        device slices its own slab); the full ``[J, L]`` scale row is
        reassembled from the shard-local tails via the static
        ``leaf_shard``/``leaf_pos`` tables (byte-exact — a ~4*L-byte
        in-pod gather, noise next to the slab payloads).
        """
        if wire.dtype != jnp.int8:
            return wire, None
        lay = self.layout
        j = wire.shape[0]
        if self.slayout is None:
            payload = wire[:, :lay.total]
            tail = wire[:, lay.total:].reshape(j, lay.num_leaves, 4)
            return payload, jax.lax.bitcast_convert_type(tail, jnp.float32)
        s = self.slayout
        w = self.shard_wire_width
        rows = wire.reshape(j, s.n_shards, w)
        payload = rows[:, :, :s.shard_total].reshape(j, lay.total)
        tails = rows[:, :, s.shard_total:].reshape(
            j, s.n_shards, s.tail_leaves, 4)
        tail = tails[:, s.leaf_shard, s.leaf_pos]          # [J, L, 4]
        return payload, jax.lax.bitcast_convert_type(tail, jnp.float32)

    def kernel_dequant_spec(self):
        return DequantSpec(per_block=False,
                           scale_width=self.layout.num_leaves)


class Fp8Codec(WireCodec):
    """float8 payload (1 B/param) with per-block f32 scales on the layout's
    block grid.

    Per block of ``block_size`` elements: ``scale = absmax / fp8_max``
    (floored so zero blocks stay decodable), payload = ``buf / scale``
    cast to the fp8 format. The f32 scales are bitcast to int8 and
    appended, so — like the int8 wire — the whole message is one
    contiguous int8 buffer (the fp8 payload bitcasts losslessly through
    the int8 container; ``decode`` bitcasts it back before the kernel's
    f32 upcast).

    Because scale granularity IS the kernel's block grid, the fused round
    dequants block b from ``scales[b]`` — one SMEM scalar per block, no
    block->leaf indirection — and under the sharded engine the scale rows
    split exactly with the slabs: each shard's tail carries only ITS
    blocks' scales (4 bytes/block), zero cross-shard redundancy, and
    decode stays slab-local without any in-pod broadcast.

    NOTE: XLA's f32 -> f8 conversion does NOT saturate in this jax pin
    (overflow becomes nan), so the scaled payload is clipped to the
    format's finite range before the cast. With absmax scaling the clip
    only catches round-off at the extremes.
    """

    def __init__(self, layout, slayout=None, *, name, qdtype):
        super().__init__(layout, slayout)
        self.name = name
        self.qdtype = jnp.dtype(qdtype)
        self.fp8_max = float(jnp.finfo(qdtype).max)

    @property
    def wire_dtype(self):
        return jnp.int8                 # container: payload + scale bytes

    @property
    def payload_dtype(self):
        return self.qdtype

    @property
    def _unsharded_width(self) -> int:
        return self.layout.total + 4 * self.layout.num_blocks

    @property
    def shard_wire_width(self) -> int:
        return self.slayout.shard_total + 4 * self.slayout.blocks_per_shard

    # ------------------------------------------------------------ scales ----
    def block_scales(self, buf: jax.Array) -> jax.Array:
        """Per-(node, block) absmax scales [J, num_blocks] (f32)."""
        lay = self.layout
        j = buf.shape[0]
        if lay.num_blocks == 0:
            return jnp.zeros((j, 0), jnp.float32)
        blocks = buf.astype(jnp.float32).reshape(j, lay.num_blocks,
                                                 lay.block_size)
        # initial=0.0 keeps all-padding blocks from reducing over nothing
        amax = jnp.abs(blocks).max(axis=2, initial=0.0)
        return (jnp.maximum(amax, 1e-12) / self.fp8_max).astype(jnp.float32)

    def scale_vector(self, scales: jax.Array) -> jax.Array:
        """Per-block scales [..., num_blocks] -> full width [..., total]."""
        return jnp.repeat(scales, self.layout.block_size, axis=-1,
                          total_repeat_length=self.layout.total)

    # ----------------------------------------------------- encode/decode ----
    def encode(self, buf):
        lay = self.layout
        j = buf.shape[0]
        scales = self.block_scales(buf)                    # [J, NB]
        scaled = buf.astype(jnp.float32) / self.scale_vector(scales)
        q = jnp.clip(scaled, -self.fp8_max, self.fp8_max).astype(self.qdtype)
        qb = jax.lax.bitcast_convert_type(q, jnp.int8)     # [J, total]
        tail = jax.lax.bitcast_convert_type(scales, jnp.int8)  # [J, NB, 4]
        if self.slayout is None:
            return jnp.concatenate([qb, tail.reshape(j, -1)], axis=1)
        s = self.slayout
        qr = qb.reshape(j, s.n_shards, s.shard_total)
        tr = tail.reshape(j, s.n_shards, 4 * s.blocks_per_shard)
        wire = jnp.concatenate([qr, tr], axis=2)
        return wire.reshape(j, s.n_shards * self.shard_wire_width)

    def decode(self, wire):
        lay = self.layout
        j = wire.shape[0]
        if self.slayout is None:
            payload = jax.lax.bitcast_convert_type(wire[:, :lay.total],
                                                   self.qdtype)
            tail = wire[:, lay.total:].reshape(j, lay.num_blocks, 4)
            return payload, jax.lax.bitcast_convert_type(tail, jnp.float32)
        s = self.slayout
        w = self.shard_wire_width
        rows = wire.reshape(j, s.n_shards, w)
        payload = jax.lax.bitcast_convert_type(
            rows[:, :, :s.shard_total].reshape(j, lay.total), self.qdtype)
        tail = rows[:, :, s.shard_total:].reshape(j, lay.num_blocks, 4)
        return payload, jax.lax.bitcast_convert_type(tail, jnp.float32)

    def kernel_dequant_spec(self):
        return DequantSpec(per_block=True,
                           scale_width=self.layout.num_blocks)
