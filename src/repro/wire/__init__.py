"""Pluggable wire-codec subsystem for the consensus exchange.

``get_codec(name, layout, slayout=None)`` builds the codec every
producer/consumer shares — the trainer's encode/decode, the async wire
ledger's row sizing, the dryrun roofline's wire accounting and the
benchmarks. ``WIRE_CODECS`` is the launcher-facing name list
(``--wire-codec``); ``resolve_codec_name`` also accepts the legacy
``ConsensusConfig.compression`` spellings (``"none"``/``""`` -> native).

See ``docs/wire_formats.md`` for the formats themselves.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.wire.codec import (DequantSpec, Fp8Codec, Int8Codec, NativeCodec,
                              WireCodec)

WIRE_CODECS = ("native", "int8", "fp8_e4m3", "fp8_e5m2")

# legacy ConsensusConfig.compression spellings
_ALIASES = {"": "native", "none": "native"}

_FP8_DTYPES = {"fp8_e4m3": jnp.float8_e4m3fn, "fp8_e5m2": jnp.float8_e5m2}


def resolve_codec_name(spec: str) -> str:
    """Codec or legacy-compression name -> canonical codec name."""
    name = _ALIASES.get(spec, spec)
    if name not in WIRE_CODECS:
        raise ValueError(f"unknown wire codec {spec!r} "
                         f"(known: {WIRE_CODECS} + legacy 'none')")
    return name


def get_codec(name: str, layout, slayout=None) -> WireCodec:
    """Build the codec for a ``FlatLayout`` (+ optional ``ShardedLayout``).

    Codecs are stateless views — building one per call site is free.
    """
    name = resolve_codec_name(name)
    if name == "native":
        return NativeCodec(layout, slayout)
    if name == "int8":
        return Int8Codec(layout, slayout)
    return Fp8Codec(layout, slayout, name=name, qdtype=_FP8_DTYPES[name])


__all__ = ["WIRE_CODECS", "DequantSpec", "Fp8Codec", "Int8Codec",
           "NativeCodec", "WireCodec", "get_codec", "resolve_codec_name"]
