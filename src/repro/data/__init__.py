from repro.data.synthetic import DataConfig, Prefetcher, SyntheticTokens

__all__ = ["DataConfig", "Prefetcher", "SyntheticTokens"]
