"""Deterministic synthetic token pipeline.

Production-shaped: per-(node, step) deterministic batches derived by key
folding (so any host can regenerate any shard — the data state checkpoint is
just the step counter), an N-deep host-side prefetcher, and a probe-batch
stream for the consensus objective evaluations (held out by key domain).

The "corpus" is a Zipf-ish synthetic LM distribution with induced bigram
structure so cross-entropy actually decreases during smoke training.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch_per_node: int
    num_nodes: int = 1
    seed: int = 0
    zipf_a: float = 1.2


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-a)
    return (p / p.sum()).astype(np.float32)


class SyntheticTokens:
    """Stateless batch source: batch(step) is pure in (seed, step, node)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._probs = _zipf_probs(cfg.vocab, cfg.zipf_a)

    def batch(self, step: int, *, probe: bool = False) -> dict:
        """Returns {tokens or labels: [J, B, S]} int32 arrays."""
        cfg = self.cfg
        domain = 1_000_003 if probe else 0
        out_tok = np.empty((cfg.num_nodes, cfg.batch_per_node, cfg.seq_len),
                           np.int32)
        for node in range(cfg.num_nodes):
            rng = np.random.default_rng(
                (cfg.seed * 7_919 + domain + node) * 2_654_435_761
                + step)
            toks = rng.choice(cfg.vocab, p=self._probs,
                              size=(cfg.batch_per_node, cfg.seq_len))
            # induced bigram structure: every even position hints the next
            toks[:, 1::2] = (toks[:, 0::2] * 31 + 7) % cfg.vocab
            out_tok[node] = toks
        labels = np.roll(out_tok, -1, axis=-1)
        labels[:, :, -1] = -1                      # masked final position
        return {"tokens": jnp.asarray(out_tok), "labels": jnp.asarray(labels)}

    def embeds_batch(self, step: int, d_model: int, *,
                     probe: bool = False) -> dict:
        """Frontend-stub variant: precomputed frame/patch embeddings."""
        b = self.batch(step, probe=probe)
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed * 13 + step + (7 if probe else 0))
        emb = rng.normal(size=(cfg.num_nodes, cfg.batch_per_node,
                               cfg.seq_len, d_model)).astype(np.float32)
        return {"embeds": jnp.asarray(emb), "labels": b["labels"]}


class Prefetcher:
    """Host-side N-deep prefetch thread over a SyntheticTokens source."""

    def __init__(self, source: SyntheticTokens, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.depth = depth
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self.source.batch(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
