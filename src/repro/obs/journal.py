"""Topology event journal: the dynamic-network story as structured events.

The paper's §4 point is that budget gating "effectively leads to an
adaptive, dynamic network topology" — the journal makes that dynamic
inspectable after the fact. It is a host-side JSONL log of TRANSITIONS
(not per-round state dumps), derived by diffing consecutive drained
``TopologyState``/``PenaltyState`` snapshots — no new traced outputs, no
extra device work: the states are already pulled at drain time.

Event types (each record: ``{"step", "event", ...}``):

  * ``edge_gated`` / ``edge_revived``   — scheduler mask flips (undirected)
  * ``stale_gated`` / ``stale_revived`` — symmetrized staleness age crossed
                                          the bound (async executor)
  * ``node_dropped``                    — churn: liveness off (ghost row)
  * ``repair_activated``                — churn repair edge switched on
                                          (ghost-row backbone rewiring)
  * ``kick_parked`` / ``kick_absorbed`` — zero-kick weights parked across a
                                          round boundary / consumed by the
                                          kernel's dual absorption
  * ``budget_exhausted``                — eq. (9) budget spent (directed)
  * ``budget_topup``                    — eq. (10) top-up raised the budget
                                          (n_incr grew; revives the edge)

Diffing drained snapshots means transitions that flip there-and-back
WITHIN one drain window coalesce away — the journal records the topology
at drain resolution (``ObsConfig.drain_every``); set ``drain_every=1`` for
round-exact journaling.
"""
from __future__ import annotations

import json
import os
from typing import IO

import numpy as np


def snapshot(topo, penalty=None) -> dict:
    """Pull the journal-relevant state to host numpy (one drain's worth)."""
    snap = {
        "mask": np.asarray(topo.mask, dtype=bool),
        "node_alive": np.asarray(topo.node_alive, dtype=bool),
        "repair": np.asarray(topo.repair, dtype=bool),
        "age": np.asarray(topo.age, dtype=np.int32),
        "kick": np.asarray(topo.kick, dtype=np.float32),
    }
    if penalty is not None:
        snap["eta"] = np.asarray(penalty.eta, dtype=np.float32)
        snap["cum_tau"] = np.asarray(penalty.cum_tau, dtype=np.float32)
        snap["budget"] = np.asarray(penalty.budget, dtype=np.float32)
        snap["n_incr"] = np.asarray(penalty.n_incr, dtype=np.int32)
    return snap


def _undirected(pairs_mask: np.ndarray):
    """Yield (i, j), i < j, for True entries of a symmetric [J, J] mask."""
    ii, jj = np.nonzero(np.triu(pairs_mask, k=1))
    return zip(ii.tolist(), jj.tolist())


def _directed(pairs_mask: np.ndarray):
    m = pairs_mask.copy()
    np.fill_diagonal(m, False)
    ii, jj = np.nonzero(m)
    return zip(ii.tolist(), jj.tolist())


def diff_events(prev: dict, cur: dict, *, step: int,
                max_staleness: int | None = None) -> list[dict]:
    """Transitions between two snapshots -> ordered list of event dicts.

    ``max_staleness`` enables the stale gate/revive events (the bound is
    executor config, not state, so the caller supplies it).
    """
    ev: list[dict] = []

    def add(event, **kw):
        ev.append({"step": int(step), "event": event, **kw})

    # -- churn first: a dropped node explains its edges' flips -----------
    for v in np.nonzero(prev["node_alive"] & ~cur["node_alive"])[0]:
        add("node_dropped", node=int(v))
    for i, j in _undirected(~prev["repair"] & cur["repair"]):
        add("repair_activated", edge=[i, j])

    # -- scheduler gate/revive (mask is symmetric) -----------------------
    sym = lambda a: a & a.T
    for i, j in _undirected(sym(prev["mask"]) & ~sym(cur["mask"])):
        add("edge_gated", edge=[i, j],
            eta=float(cur["eta"][i, j]) if "eta" in cur else None)
    for i, j in _undirected(~sym(prev["mask"]) & sym(cur["mask"])):
        add("edge_revived", edge=[i, j],
            eta=float(cur["eta"][i, j]) if "eta" in cur else None)

    # -- staleness crossings (async executor) ----------------------------
    if max_staleness is not None:
        age_p = np.maximum(prev["age"], prev["age"].T)
        age_c = np.maximum(cur["age"], cur["age"].T)
        was, now = age_p <= max_staleness, age_c <= max_staleness
        for i, j in _undirected(was & ~now):
            add("stale_gated", edge=[i, j], age=int(age_c[i, j]))
        for i, j in _undirected(~was & now):
            add("stale_revived", edge=[i, j], age=int(age_c[i, j]))

    # -- zero-kick park/absorb -------------------------------------------
    kick_p, kick_c = prev["kick"] != 0.0, cur["kick"] != 0.0
    for i, j in _undirected(~kick_p & kick_c):
        add("kick_parked", edge=[i, j], weight=float(cur["kick"][i, j]))
    for i, j in _undirected(kick_p & ~kick_c):
        add("kick_absorbed", edge=[i, j], weight=float(prev["kick"][i, j]))

    # -- budget lifecycle (directed: cum_tau_ij != cum_tau_ji) -----------
    if "budget" in cur and "budget" in prev:
        ex_p = prev["cum_tau"] >= prev["budget"]
        ex_c = cur["cum_tau"] >= cur["budget"]
        for i, j in _directed(~ex_p & ex_c):
            add("budget_exhausted", edge=[i, j],
                cum_tau=float(cur["cum_tau"][i, j]),
                budget=float(cur["budget"][i, j]))
        for i, j in _directed(cur["n_incr"] > prev["n_incr"]):
            add("budget_topup", edge=[i, j],
                n_incr=int(cur["n_incr"][i, j]),
                budget=float(cur["budget"][i, j]))
    return ev


class EventJournal:
    """Append-only JSONL journal over drained state snapshots.

    ``observe(topo, penalty, step)`` diffs against the previous snapshot,
    writes one JSON line per transition, and keeps the new snapshot. The
    first observe establishes the baseline (no events). Flushed per
    observe so a crashed run keeps its journal.
    """

    def __init__(self, path: str, *, max_staleness: int | None = None):
        self.path = path
        self.max_staleness = max_staleness
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f: IO[str] | None = open(path, "a")
        self._prev: dict | None = None
        self.num_events = 0

    def observe(self, topo, penalty=None, *, step: int) -> list[dict]:
        snap = snapshot(topo, penalty)
        events: list[dict] = []
        if self._prev is not None:
            events = diff_events(self._prev, snap, step=step,
                                 max_staleness=self.max_staleness)
            for e in events:
                self._f.write(json.dumps(e) + "\n")
            if events:
                self._f.flush()
            self.num_events += len(events)
        self._prev = snap
        return events

    def emit(self, event: dict) -> dict:
        """Append one pre-built event record to the same JSONL stream.

        The health monitor (``obs.health``) routes its ``health_*`` events
        through here so topology transitions and health findings land in
        ONE chronologically ordered journal. Flushed per emit, mirroring
        ``observe``.
        """
        self._f.write(json.dumps(event) + "\n")
        self._f.flush()
        self.num_events += 1
        return event

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
