"""The unified consensus-metrics schema — ONE key set for every round path.

Before this module existed each round flavor returned its own ad-hoc
metrics dict: the sync engine emitted five keys, the bounded-staleness
round added ``stale_edges``/``age_max``, and the ``max_staleness=0``
degenerate path padded the missing ones with zeros at its call site
(the shape drift the obs ISSUE's first satellite names). Every consumer —
the launcher's log line, the metrics ring, the exporters, the regression
benchmarks — now reads THIS registry instead:

  * ``ROUND_METRICS`` is the ordered tuple of metric names every
    consensus round emits (sync, async, replicated, sharded — identical
    key sets, pinned by ``tests/test_obs.py``);
  * ``RING_COLUMNS`` prepends the ``step`` stamp and is the column order
    of the on-device ``MetricsRing`` buffer (``obs.ring``) — the mapping
    metric name -> ring column is ``COLUMN_INDEX`` and is STABLE: new
    metrics append, existing columns never renumber (drained artifacts
    from different code versions stay comparable via
    ``SCHEMA_VERSION``).

Everything here is jit-friendly: ``unify_round_metrics`` runs inside the
traced consensus step (zero-padding is two constants), ``metrics_row``
stacks the dict into the ``[n_columns]`` f32 vector the ring stores.
"""
from __future__ import annotations

import jax.numpy as jnp

# bump when RING_COLUMNS changes meaning (append-only growth does not
# require it for readers that index by name via COLUMN_INDEX)
SCHEMA_VERSION = 1

# the unified per-round metric key set, in ring-column order. Zero is the
# defined "not applicable" value for every async-only metric on the sync
# path (no stale edges, zero max age) — the same values the async round
# reports when nothing is actually stale, so the sync/async unification
# is value-exact, not just key-exact.
ROUND_METRICS = (
    "r_max",         # max over alive nodes of the primal residual (eq. 5)
    "s_max",         # max over alive nodes of the dual residual (eq. 5)
    "f_mean",        # mean local objective over alive, connected nodes
    "eta_mean",      # mean per-edge penalty over the static graph edges
    "active_edges",  # |mask| / |adj| — the dynamic-topology gate fraction
    "stale_edges",   # fraction of masked edges gated by staleness (async)
    "age_max",       # max symmetrized staleness age on the mask (async)
)

# ring columns: the step stamp first, then the metrics in registry order
RING_COLUMNS = ("step",) + ROUND_METRICS
COLUMN_INDEX = {name: i for i, name in enumerate(RING_COLUMNS)}
NUM_COLUMNS = len(RING_COLUMNS)

# metrics that are integers in the round dicts (stored as f32 ring cells,
# exported back as ints by the drain path)
_INT_METRICS = frozenset({"age_max"})


def unify_round_metrics(metrics: dict) -> dict:
    """Pad a round's metrics dict to the full ``ROUND_METRICS`` key set.

    Traced-code safe: missing keys become constant zeros (int32 for
    ``_INT_METRICS``, f32 otherwise). Key order follows the registry, so
    two unified dicts always zip cleanly. Extra keys are rejected — a new
    metric must be registered in ``ROUND_METRICS`` (and thereby get a
    stable ring column), not smuggled past the schema.
    """
    extra = set(metrics) - set(ROUND_METRICS)
    if extra:
        raise ValueError(
            f"unregistered consensus metrics {sorted(extra)}; add them to "
            f"obs.schema.ROUND_METRICS (append-only) first")
    out = {}
    for name in ROUND_METRICS:
        if name in metrics:
            out[name] = metrics[name]
        elif name in _INT_METRICS:
            out[name] = jnp.zeros((), jnp.int32)
        else:
            out[name] = jnp.zeros((), jnp.float32)
    return out


def metrics_row(step, metrics: dict):
    """Stack a unified metrics dict into the ``[NUM_COLUMNS]`` f32 ring row.

    ``step`` is the trainer's global step counter at the round (the stamp
    the drain path keys artifacts by). Runs inside jit.
    """
    metrics = unify_round_metrics(metrics)
    cells = [jnp.asarray(step, jnp.float32)]
    cells += [jnp.asarray(metrics[name], jnp.float32)
              for name in ROUND_METRICS]
    return jnp.stack(cells)


def row_to_dict(row) -> dict:
    """One drained ring row (host array / list) -> a plain-python dict."""
    out = {}
    for name, i in COLUMN_INDEX.items():
        v = float(row[i])
        out[name] = int(v) if name in _INT_METRICS or name == "step" else v
    return out
