"""The unified consensus-metrics schema — ONE key set for every round path.

Before this module existed each round flavor returned its own ad-hoc
metrics dict: the sync engine emitted five keys, the bounded-staleness
round added ``stale_edges``/``age_max``, and the ``max_staleness=0``
degenerate path padded the missing ones with zeros at its call site
(the shape drift the obs ISSUE's first satellite names). Every consumer —
the launcher's log line, the metrics ring, the exporters, the regression
benchmarks — now reads THIS registry instead:

  * ``ROUND_METRICS`` is the ordered tuple of metric names every
    consensus round emits (sync, async, replicated, sharded — identical
    key sets, pinned by ``tests/test_obs.py``);
  * ``RING_COLUMNS`` prepends the ``step`` stamp and is the column order
    of the on-device ``MetricsRing`` buffer (``obs.ring``) — the mapping
    metric name -> ring column is ``COLUMN_INDEX`` and is STABLE: new
    metrics append, existing columns never renumber (drained artifacts
    from different code versions stay comparable via
    ``SCHEMA_VERSION``);
  * ``NODE_METRICS``/``NODE_COLUMNS`` is the same contract one level
    finer: the PER-NODE telemetry row (``obs.node_ring`` stores one
    ``[J, NUM_NODE_COLUMNS]`` slab per round) — per-node residuals,
    local objective, penalty row mean, staleness age, liveness/advance
    flags and received wire bytes, appended by the same four round
    paths through ``ConsensusTrainer._finish_round``.

The ``step`` stamp is stored EXACTLY: the int32 step id is bitcast into
the f32 cell (``encode_step``) and bitcast back on the host
(``decode_step``). Storing the step as a float value silently corrupted
ids above 2^24 (f32 has a 24-bit significand — at LM scale a long run
crosses 16.7M steps); the bitcast carries all 32 bits, at the price that
the raw cell is only meaningful through ``decode_step`` (which
``row_to_dict``/``node_row_to_dict`` apply). SCHEMA_VERSION 2 marks the
cell-meaning change.

Everything here is jit-friendly: ``unify_round_metrics`` runs inside the
traced consensus step (zero-padding is two constants), ``metrics_row``
stacks the dict into the ``[n_columns]`` f32 vector the ring stores.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# bump when RING_COLUMNS/NODE_COLUMNS change meaning (append-only growth
# does not require it for readers that index by name via COLUMN_INDEX).
# v2: step cells are int32-bitcast (exact above 2^24), NODE_COLUMNS added.
SCHEMA_VERSION = 2

# the unified per-round metric key set, in ring-column order. Zero is the
# defined "not applicable" value for every async-only metric on the sync
# path (no stale edges, zero max age) — the same values the async round
# reports when nothing is actually stale, so the sync/async unification
# is value-exact, not just key-exact.
ROUND_METRICS = (
    "r_max",         # max over alive nodes of the primal residual (eq. 5)
    "s_max",         # max over alive nodes of the dual residual (eq. 5)
    "f_mean",        # mean local objective over alive, connected nodes
    "eta_mean",      # mean per-edge penalty over the static graph edges
    "active_edges",  # |mask| / |adj| — the dynamic-topology gate fraction
    "stale_edges",   # fraction of masked edges gated by staleness (async)
    "age_max",       # max symmetrized staleness age on the mask (async)
)

# ring columns: the step stamp first, then the metrics in registry order
RING_COLUMNS = ("step",) + ROUND_METRICS
COLUMN_INDEX = {name: i for i, name in enumerate(RING_COLUMNS)}
NUM_COLUMNS = len(RING_COLUMNS)

# the per-NODE metric key set, in node-ring column order. Same registry
# rules as ROUND_METRICS: append-only, zero is the defined
# not-applicable value (sync rounds have no staleness age; a static
# topology has every node alive and advancing).
NODE_METRICS = (
    "r",              # this node's primal residual ||theta_i - bar_i||
    "s",              # this node's dual residual (eq. 5)
    "f_local",        # f_i(theta_i) on the probe batch (eq. 7 diagonal)
    "eta_row_mean",   # mean penalty over the node's graph row — "is the
                      # paper's adaptation still moving for THIS node"
    "age_max",        # max symmetrized staleness age over incident edges
    "alive",          # liveness flag (0 = ghost row after churn)
    "advance",        # did this node run a real round this fleet tick
    "wire_rx_bytes",  # fresh wire bytes this node consumed this round
)
NODE_COLUMNS = ("step",) + NODE_METRICS
NODE_COLUMN_INDEX = {name: i for i, name in enumerate(NODE_COLUMNS)}
NUM_NODE_COLUMNS = len(NODE_COLUMNS)

# metrics that are integers in the round dicts (stored as f32 ring cells,
# exported back as ints by the drain path)
_INT_METRICS = frozenset({"age_max"})
_INT_NODE_METRICS = frozenset({"age_max"})


# ------------------------------------------------------ step stamping ----
def encode_step(step):
    """int32 step id -> the exact f32 ring cell (bitcast; runs in jit)."""
    return jax.lax.bitcast_convert_type(
        jnp.asarray(step, jnp.int32), jnp.float32)


def decode_step(cell) -> int:
    """The exact step id back out of a drained f32 cell (host side)."""
    return int(np.float32(cell).view(np.int32))


def unify_round_metrics(metrics: dict) -> dict:
    """Pad a round's metrics dict to the full ``ROUND_METRICS`` key set.

    Traced-code safe: missing keys become constant zeros (int32 for
    ``_INT_METRICS``, f32 otherwise). Key order follows the registry, so
    two unified dicts always zip cleanly. Extra keys are rejected — a new
    metric must be registered in ``ROUND_METRICS`` (and thereby get a
    stable ring column), not smuggled past the schema.
    """
    extra = set(metrics) - set(ROUND_METRICS)
    if extra:
        raise ValueError(
            f"unregistered consensus metrics {sorted(extra)}; add them to "
            f"obs.schema.ROUND_METRICS (append-only) first")
    out = {}
    for name in ROUND_METRICS:
        if name in metrics:
            out[name] = metrics[name]
        elif name in _INT_METRICS:
            out[name] = jnp.zeros((), jnp.int32)
        else:
            out[name] = jnp.zeros((), jnp.float32)
    return out


def metrics_row(step, metrics: dict):
    """Stack a unified metrics dict into the ``[NUM_COLUMNS]`` f32 ring row.

    ``step`` is the trainer's global step counter at the round (the stamp
    the drain path keys artifacts by) — carried EXACTLY via the int32
    bitcast cell (see module docstring). Runs inside jit.
    """
    metrics = unify_round_metrics(metrics)
    cells = [encode_step(step)]
    cells += [jnp.asarray(metrics[name], jnp.float32)
              for name in ROUND_METRICS]
    return jnp.stack(cells)


def row_to_dict(row) -> dict:
    """One drained ring row (host array / list) -> a plain-python dict."""
    out = {}
    for name, i in COLUMN_INDEX.items():
        if name == "step":
            out[name] = decode_step(row[i])
        else:
            v = float(row[i])
            out[name] = int(v) if name in _INT_METRICS else v
    return out


# --------------------------------------------------- per-node metrics ----
def unify_node_metrics(metrics: dict, num_nodes: int) -> dict:
    """Pad a round's per-node metrics dict to the full ``NODE_METRICS``
    key set of ``[J]`` vectors.

    Missing keys become constant vectors of the defined not-applicable
    value: zeros, except the flags — an unreported ``alive``/``advance``
    means every node is live and ran the round (the sync path). Extra
    keys are rejected like ``unify_round_metrics``.
    """
    extra = set(metrics) - set(NODE_METRICS)
    if extra:
        raise ValueError(
            f"unregistered per-node metrics {sorted(extra)}; add them to "
            f"obs.schema.NODE_METRICS (append-only) first")
    out = {}
    for name in NODE_METRICS:
        if name in metrics:
            out[name] = jnp.broadcast_to(
                jnp.asarray(metrics[name]), (num_nodes,))
        elif name in ("alive", "advance"):
            out[name] = jnp.ones((num_nodes,), jnp.float32)
        elif name in _INT_NODE_METRICS:
            out[name] = jnp.zeros((num_nodes,), jnp.int32)
        else:
            out[name] = jnp.zeros((num_nodes,), jnp.float32)
    return out


def node_row(step, metrics: dict, num_nodes: int):
    """Stack per-node metrics into the ``[J, NUM_NODE_COLUMNS]`` f32 slab
    the node ring stores (one slab per round; runs inside jit)."""
    metrics = unify_node_metrics(metrics, num_nodes)
    cells = [jnp.broadcast_to(encode_step(step), (num_nodes,))]
    cells += [jnp.asarray(metrics[name], jnp.float32)
              for name in NODE_METRICS]
    return jnp.stack(cells, axis=1)


def node_row_to_dict(row) -> dict:
    """One drained ``[J, NUM_NODE_COLUMNS]`` slab -> a plain-python dict:
    ``{"step": int, "<metric>": [J values]}`` (ints for int metrics)."""
    row = np.asarray(row)
    out = {"step": decode_step(row[0, NODE_COLUMN_INDEX["step"]])}
    for name in NODE_METRICS:
        col = row[:, NODE_COLUMN_INDEX[name]]
        if name in _INT_NODE_METRICS:
            out[name] = [int(v) for v in col]
        else:
            out[name] = [float(v) for v in col]
    return out
