"""Observability subsystem: metrics ring, trace spans, topology journal.

Four pieces, one per telemetry concern (details in each module and in
``docs/observability.md``):

  * ``obs.schema``  — THE unified per-round metrics schema (key set +
    stable ring-column registry) every round path emits against.
  * ``obs.ring``    — on-device ``[cap, n_metrics]`` metrics ring riding
    in ``TrainState``; appended in-jit, drained to host every K rounds.
  * ``obs.trace``   — ``jax.named_scope`` / profiler-annotation span
    factories with the round-phase naming convention.
  * ``obs.journal`` — host-side JSONL event journal derived by diffing
    drained ``TopologyState``/``PenaltyState`` snapshots.
  * ``obs.export``  — the per-run artifact writer (``--obs-dir``):
    metrics/events JSONL, summary rollup, RoundClock Perfetto trace, and
    the artifact validator CLI.

Everything is off by default and leaves zero trace in compiled code when
off: ``ConsensusConfig.obs=None`` (or ``ObsConfig(enabled=False)``) lowers
byte-identical HLO to a build without the subsystem (pinned in
``tests/test_obs.py``).
"""
from repro.obs.export import (ObsWriter, build_rollup,
                              roundclock_trace_events, validate_obs_dir,
                              write_roundclock_trace)
from repro.obs.journal import EventJournal, diff_events, snapshot
from repro.obs.ring import (MetricsRing, ObsConfig, drain, drain_rows,
                            init_ring, ring_append)
from repro.obs.schema import (COLUMN_INDEX, NUM_COLUMNS, RING_COLUMNS,
                              ROUND_METRICS, SCHEMA_VERSION, metrics_row,
                              row_to_dict, unify_round_metrics)
from repro.obs.trace import (host_span, host_span_factory, span,
                             span_factory)

__all__ = [
    "COLUMN_INDEX", "EventJournal", "MetricsRing", "NUM_COLUMNS",
    "ObsConfig", "ObsWriter", "RING_COLUMNS", "ROUND_METRICS",
    "SCHEMA_VERSION", "build_rollup", "diff_events", "drain", "drain_rows",
    "host_span", "host_span_factory", "init_ring", "metrics_row",
    "ring_append", "roundclock_trace_events", "row_to_dict", "snapshot",
    "span", "span_factory", "unify_round_metrics", "validate_obs_dir",
    "write_roundclock_trace",
]
