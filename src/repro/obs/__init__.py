"""Observability subsystem: metrics rings, health monitor, journal, dashboard.

Pieces, one per telemetry concern (details in each module and in
``docs/observability.md``):

  * ``obs.schema``    — THE unified metrics schemas: the per-round key
    set + stable ring-column registry (``ROUND_METRICS``) and the
    per-node registry (``NODE_METRICS``) every round path emits against.
  * ``obs.ring``      — on-device ``[cap, n_metrics]`` scalar metrics
    ring riding in ``TrainState``; appended in-jit, drained every K
    rounds.
  * ``obs.node_ring`` — the per-node ``[cap, J, n_cols]`` telemetry ring
    next to it: per-node residuals, objective, penalty row means,
    staleness ages, liveness and wire bytes.
  * ``obs.trace``     — ``jax.named_scope`` / profiler-annotation span
    factories with the round-phase naming convention.
  * ``obs.journal``   — host-side JSONL event journal derived by diffing
    drained ``TopologyState``/``PenaltyState`` snapshots (plus raw
    ``emit`` for health events).
  * ``obs.health``    — online detector bank over drained node rows:
    divergence, eta stall/oscillation, straggler, consensus drift;
    per-node scores and advisory recommendations.
  * ``obs.export``    — the per-run artifact writer (``--obs-dir``):
    metrics/node-metrics/events JSONL, summary rollup, RoundClock
    Perfetto trace, and the artifact validator CLI.
  * ``obs.dashboard`` — renders one obs directory into a single
    self-contained HTML dashboard (``python -m repro.obs.dashboard``).

Everything is off by default and leaves zero trace in compiled code when
off: ``ConsensusConfig.obs=None`` (or ``ObsConfig(enabled=False)``) lowers
byte-identical HLO to a build without the subsystem (pinned in
``tests/test_obs.py``); ``ObsConfig(with_node_ring=False)`` compiles the
node ring out while keeping the scalar ring.
"""
from repro.obs.export import (ObsWriter, build_rollup,
                              roundclock_trace_events, validate_obs_dir,
                              write_roundclock_trace)
from repro.obs.health import (HEALTH_EVENTS, HealthConfig, HealthMonitor,
                              analyze_trace)
from repro.obs.journal import EventJournal, diff_events, snapshot
from repro.obs.node_ring import (NodeRing, drain_node_rows, init_node_ring,
                                 node_ring_append)
from repro.obs.ring import (MetricsRing, ObsConfig, drain, drain_rows,
                            init_ring, ring_append)
from repro.obs.schema import (COLUMN_INDEX, NODE_COLUMN_INDEX, NODE_COLUMNS,
                              NODE_METRICS, NUM_COLUMNS, NUM_NODE_COLUMNS,
                              RING_COLUMNS, ROUND_METRICS, SCHEMA_VERSION,
                              decode_step, encode_step, metrics_row,
                              node_row, node_row_to_dict, row_to_dict,
                              unify_node_metrics, unify_round_metrics)
from repro.obs.trace import (host_span, host_span_factory, span,
                             span_factory)

__all__ = [
    "COLUMN_INDEX", "EventJournal", "HEALTH_EVENTS", "HealthConfig",
    "HealthMonitor", "MetricsRing", "NODE_COLUMNS", "NODE_COLUMN_INDEX",
    "NODE_METRICS", "NUM_COLUMNS", "NUM_NODE_COLUMNS", "NodeRing",
    "ObsConfig", "ObsWriter", "RING_COLUMNS", "ROUND_METRICS",
    "SCHEMA_VERSION", "analyze_trace", "build_rollup", "decode_step",
    "diff_events", "drain", "drain_node_rows", "drain_rows", "encode_step",
    "host_span", "host_span_factory", "init_node_ring", "init_ring",
    "metrics_row", "node_ring_append", "node_row", "node_row_to_dict",
    "ring_append", "roundclock_trace_events", "row_to_dict", "snapshot",
    "span", "span_factory", "unify_node_metrics", "unify_round_metrics",
    "validate_obs_dir", "write_roundclock_trace",
]
