"""On-device metrics ring: per-round telemetry with zero per-round host syncs.

The consensus step already computes its metrics on device; what used to
make telemetry expensive was the per-round device->host pull (a sync point
that serializes the round pipeline). The ring removes it: a fixed-capacity
``[cap, NUM_COLUMNS]`` f32 buffer rides in ``TrainState`` and each round
appends its ``obs.schema.metrics_row`` in-jit via one
``dynamic_update_slice`` — O(NUM_COLUMNS) bytes of HBM traffic per round,
within noise of the fused round itself (gated <= 3% by ``BENCH_obs.json``).
The host drains the buffer only every K rounds (``ObsConfig.drain_every``),
so steady-state training never blocks on telemetry.

Buffer discipline:

  * ``head`` counts appends MONOTONICALLY; the write slot is
    ``head % cap``. The drain path never writes the device state back —
    the host keeps its own cursor (the last drained head) and reads the
    rows in ``[cursor, head)``, so draining is a pure read and composes
    with state donation (the ring is donated with the rest of the
    TrainState; the drain reads the LIVE output buffers between steps).
  * overflow is explicit, not silent: if more than ``cap`` rounds ran
    since the last drain, the oldest rows were overwritten and ``drain``
    reports how many were dropped (the exporters surface it in the
    rollup). Size ``cap >= drain_every`` to never drop.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import schema


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Knobs for the observability subsystem (``ConsensusConfig.obs``).

    Attributes:
      enabled: master switch. ``ObsConfig(enabled=False)`` is pinned to
        lower BYTE-IDENTICAL HLO to ``obs=None`` — the subsystem leaves
        zero trace in the compiled step when off (tests/test_obs.py).
      ring_capacity: rows in the on-device metrics ring. Must be >=
        ``drain_every`` or steady-state drains drop rows (allowed but
        reported).
      drain_every: host drain cadence in CONSENSUS ROUNDS (the K of the
        amortized-drain accounting in ``launch.dryrun``).
      with_spans: wrap the traced round phases (pack, permute, decode,
        probe, fused kernel) in ``jax.named_scope`` spans and the host
        round calls in profiler TraceAnnotations (``obs.trace``).
      with_node_ring: carry the per-node telemetry ring
        (``obs.node_ring``: ``[cap, J, NODE_COLUMNS]``) next to the
        scalar ring — per-node residuals, objective, penalty row means,
        staleness ages, liveness and wire bytes, the inputs the health
        monitor (``obs.health``) and the dashboard's per-node heatmaps
        read. Shares ``ring_capacity``/``drain_every``. False keeps the
        scalar-ring-only PR 7 footprint.
    """

    enabled: bool = True
    ring_capacity: int = 256
    drain_every: int = 8
    with_spans: bool = True
    with_node_ring: bool = True

    def __post_init__(self):
        if self.ring_capacity < 1:
            raise ValueError(f"ring_capacity {self.ring_capacity} < 1")
        if self.drain_every < 1:
            raise ValueError(f"drain_every {self.drain_every} < 1")


class MetricsRing(NamedTuple):
    """Traced fixed-capacity metrics buffer (rides in ``TrainState``)."""

    buf: jax.Array    # [cap, schema.NUM_COLUMNS] f32 — rows, slot = k % cap
    head: jax.Array   # [] int32 — MONOTONIC append count (next write id)


def init_ring(capacity: int) -> MetricsRing:
    return MetricsRing(
        buf=jnp.zeros((int(capacity), schema.NUM_COLUMNS), jnp.float32),
        head=jnp.zeros((), jnp.int32))


def ring_append(ring: MetricsRing, row: jax.Array) -> MetricsRing:
    """Append one ``[NUM_COLUMNS]`` row in-jit (one dynamic_update_slice)."""
    cap = ring.buf.shape[0]
    slot = jax.lax.rem(ring.head, jnp.int32(cap))
    buf = jax.lax.dynamic_update_slice(ring.buf, row[None, :].astype(
        ring.buf.dtype), (slot, jnp.int32(0)))
    return MetricsRing(buf=buf, head=ring.head + 1)


def drain(ring: MetricsRing, cursor: int
          ) -> tuple[np.ndarray, int, int]:
    """Host-side read of every row appended since ``cursor``.

    Returns ``(rows, new_cursor, dropped)`` with ``rows`` a
    ``[n, NUM_COLUMNS]`` numpy array in CHRONOLOGICAL order, ``new_cursor``
    the head to pass next time, and ``dropped`` the count of rows
    overwritten before this drain could read them (0 unless more than
    ``cap`` rounds ran since the last drain). Pure read: the device state
    is never written back, so the caller's jitted steps keep donating the
    ring buffer.
    """
    head = int(ring.head)
    cap = int(ring.buf.shape[0])
    n_new = head - cursor
    if n_new <= 0:
        return np.zeros((0, schema.NUM_COLUMNS), np.float32), head, 0
    dropped = max(0, n_new - cap)
    take = n_new - dropped
    buf = np.asarray(ring.buf)
    idx = (np.arange(head - take, head)) % cap
    return buf[idx], head, dropped


def drain_rows(ring: MetricsRing, cursor: int
               ) -> tuple[list[dict], int, int]:
    """``drain`` + per-row dict conversion (``obs.schema.row_to_dict``)."""
    rows, new_cursor, dropped = drain(ring, cursor)
    return [schema.row_to_dict(r) for r in rows], new_cursor, dropped
