"""On-device PER-NODE telemetry ring: who is diverging, not just whether.

The scalar ``obs.ring`` answers "is the fleet converging" with one
``[NUM_COLUMNS]`` row per round (``r_max``, ``eta_mean``, ...). It cannot
answer the questions the paper's adaptation machinery raises in
production: WHICH node's residual is growing, WHICH node's penalties have
stopped moving, which pod is the straggler the age distribution points
at. This ring carries that level: a ``[cap, J, NUM_NODE_COLUMNS]`` f32
buffer riding in ``TrainState`` next to the scalar ring, one ``[J,
NUM_NODE_COLUMNS]`` slab appended per consensus round on all four round
paths (sync/async x replicated/sharded) through
``ConsensusTrainer._finish_round``.

Everything per-node the round already computes rides along for free: the
fused kernel's blockwise residual partials reduce to PER-NODE ``r_i`` /
``s_i`` vectors before the scalar extremes are taken (with
``shard_consensus`` the in-pod psum finishes them — the rows here are the
post-psum, replicated values, so sharded == replicated holds by
construction and is pinned by test). The column registry is
``obs.schema.NODE_COLUMNS`` — append-only, step stamps carried exactly
via the int32-bitcast cell.

Buffer discipline is IDENTICAL to the scalar ring (same monotonic head,
same pure-read host cursor, same explicit dropped-row accounting) so the
two rings drain with one discipline; the slab is J x wider, which is why
the ring is separately gated (``ObsConfig.with_node_ring``) and
separately priced in ``BENCH_obs.json`` (node ring <= 3 points over the
scalar-ring baseline).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import schema


class NodeRing(NamedTuple):
    """Traced fixed-capacity per-node buffer (rides in ``TrainState``)."""

    buf: jax.Array    # [cap, J, NUM_NODE_COLUMNS] f32 — slot = k % cap
    head: jax.Array   # [] int32 — MONOTONIC append count (next write id)


def init_node_ring(capacity: int, num_nodes: int) -> NodeRing:
    return NodeRing(
        buf=jnp.zeros((int(capacity), int(num_nodes),
                       schema.NUM_NODE_COLUMNS), jnp.float32),
        head=jnp.zeros((), jnp.int32))


def node_ring_append(ring: NodeRing, row: jax.Array) -> NodeRing:
    """Append one ``[J, NUM_NODE_COLUMNS]`` slab in-jit (one
    dynamic_update_slice, exactly like the scalar ring)."""
    cap = ring.buf.shape[0]
    slot = jax.lax.rem(ring.head, jnp.int32(cap))
    buf = jax.lax.dynamic_update_slice(
        ring.buf, row[None].astype(ring.buf.dtype),
        (slot, jnp.int32(0), jnp.int32(0)))
    return NodeRing(buf=buf, head=ring.head + 1)


def drain(ring: NodeRing, cursor: int
          ) -> tuple[np.ndarray, int, int]:
    """Host-side pure read of every slab appended since ``cursor``.

    Returns ``(rows, new_cursor, dropped)`` — ``rows`` is ``[n, J,
    NUM_NODE_COLUMNS]`` in CHRONOLOGICAL order; semantics match
    ``obs.ring.drain`` exactly (monotonic head, host cursor, explicit
    overflow count, device state never written back).
    """
    head = int(ring.head)
    cap = int(ring.buf.shape[0])
    n_new = head - cursor
    if n_new <= 0:
        return np.zeros((0,) + ring.buf.shape[1:], np.float32), head, 0
    dropped = max(0, n_new - cap)
    take = n_new - dropped
    buf = np.asarray(ring.buf)
    idx = (np.arange(head - take, head)) % cap
    return buf[idx], head, dropped


def drain_node_rows(ring: NodeRing, cursor: int
                    ) -> tuple[list[dict], int, int]:
    """``drain`` + per-slab dict conversion (``schema.node_row_to_dict``)."""
    rows, new_cursor, dropped = drain(ring, cursor)
    return [schema.node_row_to_dict(r) for r in rows], new_cursor, dropped
