"""Obs dashboard: one obs directory -> ONE self-contained HTML file.

``python -m repro.obs.dashboard <obs-dir>`` renders any ``ObsWriter``
artifact set (run.json, metrics.jsonl, node_metrics.jsonl, events.jsonl,
rollup.json) into a single browsable HTML file with zero external
dependencies — every chart is inline SVG, every byte of data is embedded,
so the file survives as a CI artifact and opens anywhere.

Sections:

  * a KPI row (rounds, final residual, host round_ms, journal events),
  * convergence curves (r/s residuals, objective, penalty mean, edge
    fractions) as small-multiple line charts — one axis each, never two
    scales on one plot,
  * per-node heatmaps (primal residual, staleness age) on one-hue
    sequential ramps — rows are nodes, columns are drained rounds,
  * the topology/health event timeline — one lane per event type so
    identity is carried by position, with health lanes in the reserved
    status colors (icon + label, never color alone),
  * the per-node health table + advisory recommendations when the run's
    rollup carries them (``ObsWriter(health=True)``).

Self-check: the file embeds a JSON manifest of every series/section id it
promises to render; ``--check`` re-reads the HTML and verifies each
promised id is present (CI runs render + check on every obs-lane drill).

Colors are the repo-wide validated reference palette (categorical slots
are used at most two per chart; the sequential ramps are single-hue;
status colors are reserved for health severity) — values are taken
verbatim from the validated reference set, not invented here.
"""
from __future__ import annotations

import argparse
import html
import json
import os
import sys

import numpy as np

from repro.obs import export as export_lib
from repro.obs import schema

# ---------------------------------------------------------------- palette ----
# Verbatim reference palette values (validated set; light mode).
INK = "#0b0b0b"
INK_2 = "#52514e"
MUTED = "#898781"
GRID = "#e1e0d9"
AXIS = "#c3c2b7"
SURFACE = "#fcfcfb"
PAGE = "#f9f9f7"
SERIES_1 = "#2a78d6"   # categorical slot 1 (blue)
SERIES_2 = "#eb6834"   # categorical slot 2 (orange)
STATUS = {"good": "#0ca30c", "warning": "#fab219",
          "serious": "#ec835a", "critical": "#d03b3b"}
# one-hue sequential ramps, light -> dark (blue is the reference ramp;
# orange is the second sequential context per the palette's rule)
BLUE_RAMP = ["#cde2fb", "#b7d3f6", "#9ec5f4", "#86b6ef", "#6da7ec",
             "#5598e7", "#3987e5", "#2a78d6", "#256abf", "#1c5cab",
             "#184f95", "#104281", "#0d366b"]
ORANGE_RAMP = ["#fbe3d6", "#f8d2bc", "#f5c1a3", "#f3b08a", "#f09e71",
               "#ee8d58", "#eb7c40", "#e16a31", "#c95d2a", "#b05023",
               "#98441c", "#803815", "#672c0e"]

# health event name -> (status role, glyph) — icon + label, never color
# alone (status colors are reserved for state, which health IS)
HEALTH_LANES = {
    "health_divergence": ("critical", "▲"),
    "health_drift": ("critical", "▲"),
    "health_eta_stall": ("warning", "■"),
    "health_eta_oscillation": ("warning", "■"),
    "health_straggler": ("serious", "●"),
}


# ------------------------------------------------------------- load layer ----
def load_obs_dir(obs_dir: str) -> dict:
    """Read every artifact the writer may have left (missing -> empty)."""

    def jsonl(name):
        path = os.path.join(obs_dir, name)
        if not os.path.exists(path):
            return []
        with open(path) as f:
            return [json.loads(ln) for ln in f if ln.strip()]

    def jsonf(name):
        path = os.path.join(obs_dir, name)
        if not os.path.exists(path):
            return {}
        with open(path) as f:
            return json.load(f)

    return {
        "dir": obs_dir,
        "meta": jsonf(export_lib.META_FILE),
        "rows": jsonl(export_lib.METRICS_FILE),
        "node_rows": jsonl(export_lib.NODE_METRICS_FILE),
        "events": jsonl(export_lib.EVENTS_FILE),
        "rollup": jsonf(export_lib.ROLLUP_FILE),
    }


# ------------------------------------------------------------ svg helpers ----
def _nice_ticks(lo: float, hi: float, n: int = 4) -> list[float]:
    if hi <= lo:
        hi = lo + 1.0
    raw = (hi - lo) / max(n, 1)
    mag = 10.0 ** np.floor(np.log10(raw))
    for m in (1, 2, 2.5, 5, 10):
        if raw <= m * mag:
            step = m * mag
            break
    t0 = np.ceil(lo / step) * step
    ticks = []
    t = t0
    while t <= hi + 1e-9 * step:
        ticks.append(float(t))
        t += step
    return ticks


def _fmt(v: float) -> str:
    if v == 0:
        return "0"
    a = abs(v)
    if a >= 1e4 or a < 1e-3:
        return f"{v:.1e}"
    if a >= 100:
        return f"{v:,.0f}"
    if a >= 1:
        return f"{v:.3g}"
    return f"{v:.3g}"


def _esc(s) -> str:
    return html.escape(str(s), quote=True)


def line_chart(chart_id: str, title: str,
               series: list[tuple[str, list[float], list[float], str]],
               *, width: int = 420, height: int = 190,
               y_label: str = "") -> str:
    """One small-multiple line chart: 2px lines, hairline grid, ONE axis,
    end markers with a surface ring, legend for >= 2 series + direct end
    labels. ``series`` is ``[(name, xs, ys, color), ...]``."""
    pad_l, pad_r, pad_t, pad_b = 46, 74, 30, 26
    pw, ph = width - pad_l - pad_r, height - pad_t - pad_b
    xs_all = [x for _, xs, _, _ in series for x in xs]
    ys_all = [y for _, _, ys, _ in series for y in ys]
    if not xs_all:
        return (f'<svg id="series-{chart_id}" class="chart" width="{width}"'
                f' height="{height}"><text x="{width / 2}" y="{height / 2}"'
                f' text-anchor="middle" fill="{MUTED}" font-size="12">'
                f'{_esc(title)}: no data</text></svg>')
    x0, x1 = min(xs_all), max(xs_all)
    y0, y1 = min(ys_all), max(ys_all)
    if x1 == x0:
        x1 = x0 + 1
    if y1 == y0:
        y0, y1 = y0 - 0.5, y1 + 0.5
    y0 = min(y0, 0.0) if y0 > 0 and y0 / max(abs(y1), 1e-12) < 0.3 else y0

    def sx(x):
        return pad_l + pw * (x - x0) / (x1 - x0)

    def sy(y):
        return pad_t + ph * (1 - (y - y0) / (y1 - y0))

    out = [f'<svg id="series-{chart_id}" class="chart line-chart" '
           f'width="{width}" height="{height}" '
           f'data-chart="{_esc(chart_id)}" role="img" '
           f'aria-label="{_esc(title)}">']
    out.append(f'<text x="{pad_l}" y="16" fill="{INK}" font-size="12" '
               f'font-weight="600">{_esc(title)}</text>')
    for t in _nice_ticks(y0, y1):
        y = sy(t)
        out.append(f'<line x1="{pad_l}" y1="{y:.1f}" '
                   f'x2="{width - pad_r}" y2="{y:.1f}" '
                   f'stroke="{GRID}" stroke-width="1"/>')
        out.append(f'<text x="{pad_l - 5}" y="{y + 3.5:.1f}" '
                   f'text-anchor="end" fill="{MUTED}" font-size="9.5">'
                   f'{_fmt(t)}</text>')
    for t in _nice_ticks(x0, x1, 5):
        out.append(f'<text x="{sx(t):.1f}" y="{height - 8}" '
                   f'text-anchor="middle" fill="{MUTED}" font-size="9.5">'
                   f'{_fmt(t)}</text>')
    out.append(f'<line x1="{pad_l}" y1="{pad_t + ph}" '
               f'x2="{width - pad_r}" y2="{pad_t + ph}" '
               f'stroke="{AXIS}" stroke-width="1"/>')
    for name, xs, ys, color in series:
        pts = " ".join(f"{sx(x):.1f},{sy(y):.1f}" for x, y in zip(xs, ys))
        out.append(f'<polyline points="{pts}" fill="none" stroke="{color}" '
                   f'stroke-width="2" stroke-linejoin="round" '
                   f'stroke-linecap="round"/>')
        # end marker: r>=4 fill + 2px surface ring, then the direct label
        ex, ey = sx(xs[-1]), sy(ys[-1])
        out.append(f'<circle cx="{ex:.1f}" cy="{ey:.1f}" r="6" '
                   f'fill="{SURFACE}"/>')
        out.append(f'<circle cx="{ex:.1f}" cy="{ey:.1f}" r="4" '
                   f'fill="{color}"/>')
        out.append(f'<text x="{ex + 8:.1f}" y="{ey + 3.5:.1f}" '
                   f'fill="{INK_2}" font-size="10">'
                   f'{_esc(name)} {_fmt(ys[-1])}</text>')
    if len(series) >= 2:       # legend: the dependable identity channel
        lx = pad_l
        for name, _, _, color in series:
            out.append(f'<rect x="{lx}" y="{pad_t - 8}" width="10" '
                       f'height="10" rx="2" fill="{color}"/>')
            out.append(f'<text x="{lx + 14}" y="{pad_t + 1}" '
                       f'fill="{INK_2}" font-size="10">{_esc(name)}</text>')
            lx += 20 + 6 * len(name)
    payload = {"title": title, "series": [
        {"name": n, "xs": list(map(float, xs)), "ys": list(map(float, ys)),
         "color": c} for n, xs, ys, c in series],
        "pad": [pad_l, pad_r, pad_t, pad_b]}
    out.append(f'<metadata class="chart-data">'
               f'{_esc(json.dumps(payload))}</metadata>')
    out.append("</svg>")
    return "".join(out)


def _ramp(v: float, vmax: float, ramp: list[str]) -> str:
    if vmax <= 0:
        return ramp[0]
    t = min(max(v / vmax, 0.0), 1.0)
    return ramp[int(round(t * (len(ramp) - 1)))]


def heatmap(chart_id: str, title: str, grid: list[list[float]],
            steps: list[int], *, ramp: list[str], unit: str = "",
            width: int = 640, int_vals: bool = False) -> str:
    """Per-node heatmap: rows = nodes, columns = drained rounds, one-hue
    sequential ramp (more = darker), 1px surface gaps, native per-cell
    tooltips. ``grid[i][t]`` is node i at drained round t."""
    j = len(grid)
    t_n = len(grid[0]) if j else 0
    pad_l, pad_t, pad_b = 46, 30, 24
    cell_h = max(10, min(22, 180 // max(j, 1)))
    pw = width - pad_l - 10
    cell_w = max(2.0, pw / max(t_n, 1))
    height = pad_t + j * cell_h + pad_b
    vmax = max((v for row in grid for v in row), default=0.0)
    out = [f'<svg id="series-{chart_id}" class="chart" width="{width}" '
           f'height="{height}" role="img" aria-label="{_esc(title)}">']
    out.append(f'<text x="{pad_l}" y="16" fill="{INK}" font-size="12" '
               f'font-weight="600">{_esc(title)}</text>')
    out.append(f'<text x="{width - 10}" y="16" text-anchor="end" '
               f'fill="{MUTED}" font-size="10">max '
               f'{_fmt(vmax)}{_esc(unit)}</text>')
    for i in range(j):
        y = pad_t + i * cell_h
        out.append(f'<text x="{pad_l - 6}" y="{y + cell_h / 2 + 3.5:.1f}" '
                   f'text-anchor="end" fill="{MUTED}" font-size="9.5">'
                   f'n{i}</text>')
        for t in range(t_n):
            v = grid[i][t]
            vtxt = str(int(v)) if int_vals else _fmt(v)
            out.append(
                f'<rect x="{pad_l + t * cell_w:.1f}" y="{y}" '
                f'width="{max(cell_w - 1, 1):.1f}" '
                f'height="{cell_h - 1}" '
                f'fill="{_ramp(v, vmax, ramp)}">'
                f'<title>node {i}, step {steps[t]}: {vtxt}{_esc(unit)}'
                f'</title></rect>')
    if t_n:
        for k in (0, t_n - 1):
            out.append(f'<text x="{pad_l + (k + 0.5) * cell_w:.1f}" '
                       f'y="{height - 8}" text-anchor="middle" '
                       f'fill="{MUTED}" font-size="9.5">'
                       f'step {steps[k]}</text>')
    # scale legend for the ramp (sequential needs one)
    sw = 90
    for n, c in enumerate(ramp):
        out.append(f'<rect x="{width - 10 - sw + n * sw / len(ramp):.1f}" '
                   f'y="{height - 16}" width="{sw / len(ramp):.1f}" '
                   f'height="8" fill="{c}"/>')
    out.append(f'<text x="{width - 10 - sw - 4}" y="{height - 8}" '
               f'text-anchor="end" fill="{MUTED}" font-size="9">0 → '
               f'{_fmt(vmax)}</text>')
    out.append("</svg>")
    return "".join(out)


def event_timeline(chart_id: str, events: list[dict], x0: int, x1: int,
                   *, width: int = 920) -> str:
    """One lane per event type (identity by position, not color); health
    lanes wear the reserved status colors with a glyph + label."""
    lanes: dict[str, list[dict]] = {}
    for e in events:
        lanes.setdefault(e.get("event", "?"), []).append(e)
    names = sorted(lanes, key=lambda n: (n.startswith("health_"), n))
    pad_l, pad_t, lane_h, pad_b = 190, 28, 20, 22
    height = pad_t + max(len(names), 1) * lane_h + pad_b
    if x1 <= x0:
        x1 = x0 + 1
    pw = width - pad_l - 16

    def sx(x):
        return pad_l + pw * (x - x0) / (x1 - x0)

    out = [f'<svg id="series-{chart_id}" class="chart" width="{width}" '
           f'height="{height}" role="img" '
           f'aria-label="topology and health event timeline">']
    out.append(f'<text x="{pad_l}" y="16" fill="{INK}" font-size="12" '
               f'font-weight="600">Topology &amp; health events</text>')
    if not names:
        out.append(f'<text x="{pad_l}" y="{pad_t + 14}" fill="{MUTED}" '
                   f'font-size="11">no events in this run</text>')
    for k, name in enumerate(names):
        y = pad_t + k * lane_h + lane_h / 2
        role_glyph = HEALTH_LANES.get(name)
        color = STATUS[role_glyph[0]] if role_glyph else SERIES_1
        glyph = (role_glyph[1] + " ") if role_glyph else ""
        out.append(f'<text x="{pad_l - 8}" y="{y + 3.5:.1f}" '
                   f'text-anchor="end" fill="{INK_2}" font-size="10">'
                   f'{glyph}{_esc(name)} ({len(lanes[name])})</text>')
        out.append(f'<line x1="{pad_l}" y1="{y:.1f}" x2="{width - 16}" '
                   f'y2="{y:.1f}" stroke="{GRID}" stroke-width="1"/>')
        for e in lanes[name]:
            tip = json.dumps({k2: v for k2, v in e.items()
                              if k2 != "event"})
            out.append(f'<circle cx="{sx(e.get("step", x0)):.1f}" '
                       f'cy="{y:.1f}" r="4" fill="{color}">'
                       f'<title>{_esc(name)} {_esc(tip)}</title></circle>')
    for t in _nice_ticks(x0, x1, 6):
        out.append(f'<text x="{sx(t):.1f}" y="{height - 6}" '
                   f'text-anchor="middle" fill="{MUTED}" font-size="9.5">'
                   f'{_fmt(t)}</text>')
    out.append("</svg>")
    return "".join(out)


# ---------------------------------------------------------- page assembly ----
def _stat_tile(label: str, value: str, note: str = "") -> str:
    return (f'<div class="tile"><div class="tile-label">{_esc(label)}</div>'
            f'<div class="tile-value">{_esc(value)}</div>'
            + (f'<div class="tile-note">{_esc(note)}</div>' if note else "")
            + "</div>")


def _health_table(health: dict) -> str:
    rows = []
    for n in health.get("nodes", []):
        active = [k for k in ("divergence", "eta_stall", "eta_oscillation",
                              "straggler", "drift") if n.get(k)]
        score = n.get("score", 1.0)
        role = ("good" if score >= 0.8 else
                "warning" if score >= 0.5 else "critical")
        glyph = {"good": "✓", "warning": "■", "critical": "▲"}[role]
        chip = (f'<span class="chip" style="background:{STATUS[role]}1a;">'
                f'<span style="color:{STATUS[role]}">{glyph}</span> '
                f'{score:.2f}</span>')
        rows.append(
            f'<tr><td>node {n.get("node")}</td><td>{chip}</td>'
            f'<td>{_esc(", ".join(active) or "—")}</td>'
            f'<td>{_esc(json.dumps(n.get("fires", {})) if n.get("fires") else "—")}</td>'
            f'<td>{n.get("lag", 0)}</td></tr>')
    return ('<table id="series-health_table" class="health">'
            '<thead><tr><th>node</th><th>score</th><th>active states</th>'
            '<th>episodes</th><th>clock lag</th></tr></thead>'
            '<tbody>' + "".join(rows) + "</tbody></table>")


_CSS = f"""
body {{ margin: 0; background: {PAGE}; color: {INK};
       font: 13px/1.45 system-ui, -apple-system, "Segoe UI", sans-serif; }}
.wrap {{ max-width: 1000px; margin: 0 auto; padding: 20px 24px 48px; }}
h1 {{ font-size: 18px; margin: 6px 0 2px; }}
h2 {{ font-size: 14px; margin: 26px 0 8px; color: {INK}; }}
.meta {{ color: {INK_2}; font-size: 12px; }}
.panel {{ background: {SURFACE}; border: 1px solid rgba(11,11,11,0.10);
          border-radius: 8px; padding: 12px; margin: 8px 0; }}
.row {{ display: flex; flex-wrap: wrap; gap: 12px; }}
.tile {{ background: {SURFACE}; border: 1px solid rgba(11,11,11,0.10);
         border-radius: 8px; padding: 10px 14px; min-width: 120px; }}
.tile-label {{ color: {INK_2}; font-size: 11px; }}
.tile-value {{ font-size: 26px; font-weight: 600; }}
.tile-note {{ color: {MUTED}; font-size: 10.5px; }}
table.health {{ border-collapse: collapse; font-size: 12px; width: 100%; }}
table.health th {{ text-align: left; color: {INK_2}; font-weight: 600;
                   border-bottom: 1px solid {AXIS}; padding: 4px 10px; }}
table.health td {{ border-bottom: 1px solid {GRID}; padding: 4px 10px;
                   font-variant-numeric: tabular-nums; }}
.chip {{ border-radius: 10px; padding: 1px 8px; }}
.recs {{ color: {INK_2}; font-size: 12px; }}
.recs li {{ margin: 2px 0; }}
#tooltip {{ position: fixed; display: none; pointer-events: none;
            background: {SURFACE}; border: 1px solid rgba(11,11,11,0.18);
            border-radius: 6px; padding: 6px 9px; font-size: 11px;
            box-shadow: 0 2px 8px rgba(11,11,11,0.12); z-index: 10; }}
#tooltip .t-name {{ color: {INK_2}; }}
"""

_JS = """
// crosshair + tooltip over every line chart (nearest-x, all series)
const tip = document.getElementById('tooltip');
for (const svg of document.querySelectorAll('svg.line-chart')) {
  const meta = svg.querySelector('metadata.chart-data');
  if (!meta) continue;
  const data = JSON.parse(meta.textContent);
  const [padL, padR, padT, padB] = data.pad;
  const W = svg.width.baseVal.value, H = svg.height.baseVal.value;
  const xsAll = data.series.flatMap(s => s.xs);
  const x0 = Math.min(...xsAll), x1 = Math.max(...xsAll, x0 + 1);
  const cross = document.createElementNS('http://www.w3.org/2000/svg', 'line');
  cross.setAttribute('stroke', '#c3c2b7');
  cross.setAttribute('stroke-width', '1');
  cross.style.display = 'none';
  svg.appendChild(cross);
  svg.addEventListener('mousemove', ev => {
    const r = svg.getBoundingClientRect();
    const px = ev.clientX - r.left;
    const fx = x0 + (px - padL) / (W - padL - padR) * (x1 - x0);
    let best = null, bestD = Infinity;
    for (const s of data.series)
      for (let i = 0; i < s.xs.length; i++) {
        const d = Math.abs(s.xs[i] - fx);
        if (d < bestD) { bestD = d; best = s.xs[i]; }
      }
    if (best === null) return;
    const sx = padL + (best - x0) / (x1 - x0) * (W - padL - padR);
    cross.setAttribute('x1', sx); cross.setAttribute('x2', sx);
    cross.setAttribute('y1', padT); cross.setAttribute('y2', H - padB);
    cross.style.display = '';
    let rows = `<div class="t-name">step ${best}</div>`;
    for (const s of data.series) {
      const i = s.xs.indexOf(best);
      if (i >= 0) rows += `<div><span style="color:${s.color}">●</span> ` +
        `${s.name}: ${Number(s.ys[i].toPrecision(4))}</div>`;
    }
    tip.innerHTML = rows;
    tip.style.display = 'block';
    tip.style.left = (ev.clientX + 14) + 'px';
    tip.style.top = (ev.clientY + 10) + 'px';
  });
  svg.addEventListener('mouseleave', () => {
    cross.style.display = 'none'; tip.style.display = 'none';
  });
}
"""


def render_dashboard(obs_dir: str, out_path: str | None = None) -> str:
    """Render one obs directory into a self-contained HTML dashboard."""
    d = load_obs_dir(obs_dir)
    rows, node_rows, events = d["rows"], d["node_rows"], d["events"]
    rollup, meta = d["rollup"], d["meta"]
    steps = [int(r["step"]) for r in rows]
    manifest: list[str] = []
    parts: list[str] = []

    def series(key):
        return [float(r[key]) for r in rows]

    # ---- KPI row -------------------------------------------------------
    timing = rollup.get("timing", {}) or {}
    round_ms = timing.get("round_ms")
    health = rollup.get("health")
    tiles = [
        _stat_tile("Consensus rounds", str(len(rows)),
                   f"{rollup.get('dropped_rows', 0)} dropped"),
        _stat_tile("Final r_max",
                   _fmt(series("r_max")[-1]) if rows else "—"),
        _stat_tile("Host round time",
                   f"{round_ms:.1f} ms" if round_ms else "—",
                   f"{timing.get('drains', 0)} drains"),
        _stat_tile("Journal events", str(len(events))),
    ]
    if health:
        scores = [n.get("score", 1.0) for n in health.get("nodes", [])]
        tiles.append(_stat_tile(
            "Healthy nodes",
            f"{sum(s >= 0.8 for s in scores)}/{len(scores)}",
            f"min score {min(scores):.2f}" if scores else ""))
    parts.append('<div class="row">' + "".join(tiles) + "</div>")

    # ---- convergence small multiples (one axis each) -------------------
    charts = []
    if rows:
        charts.append(line_chart(
            "residuals", "Residuals (eq. 5)",
            [("r_max", steps, series("r_max"), SERIES_1),
             ("s_max", steps, series("s_max"), SERIES_2)]))
        charts.append(line_chart(
            "f_mean", "Mean local objective",
            [("f_mean", steps, series("f_mean"), SERIES_1)]))
        charts.append(line_chart(
            "eta_mean", "Mean penalty (eq. 7-9)",
            [("eta_mean", steps, series("eta_mean"), SERIES_1)]))
        charts.append(line_chart(
            "edges", "Edge fractions",
            [("active", steps, series("active_edges"), SERIES_1),
             ("stale", steps, series("stale_edges"), SERIES_2)]))
        manifest += ["residuals", "f_mean", "eta_mean", "edges"]
    parts.append("<h2>Convergence</h2><div class='panel'><div class='row'>"
                 + "".join(charts) + "</div></div>")

    # ---- per-node heatmaps ---------------------------------------------
    if node_rows:
        nsteps = [int(r["step"]) for r in node_rows]
        j = len(node_rows[0]["r"])
        r_grid = [[float(nr["r"][i]) for nr in node_rows] for i in range(j)]
        a_grid = [[float(nr["age_max"][i]) for nr in node_rows]
                  for i in range(j)]
        parts.append(
            "<h2>Per-node telemetry</h2><div class='panel'>"
            + heatmap("node_r", "Per-node primal residual r_i",
                      r_grid, nsteps, ramp=BLUE_RAMP)
            + heatmap("node_age", "Per-node staleness age (rounds)",
                      a_grid, nsteps, ramp=ORANGE_RAMP, int_vals=True)
            + "</div>")
        manifest += ["node_r", "node_age"]

    # ---- event timeline -------------------------------------------------
    x0 = min(steps) if steps else 0
    x1 = max(steps) if steps else 1
    parts.append("<h2>Events</h2><div class='panel'>"
                 + event_timeline("events", events, x0, x1) + "</div>")
    manifest.append("events")

    # ---- health ---------------------------------------------------------
    if health:
        recs = health.get("recommendations", {})
        rec_html = ""
        if recs.get("notes"):
            rec_html = ("<ul class='recs'>" + "".join(
                f"<li>{_esc(n)}</li>" for n in recs["notes"]) + "</ul>")
        else:
            rec_html = "<div class='recs'>no advisories</div>"
        parts.append("<h2>Health</h2><div class='panel'>"
                     + _health_table(health)
                     + "<h2>Advisory recommendations</h2>" + rec_html
                     + "</div>")
        manifest.append("health_table")

    codec = meta.get("wire_codec", "?")
    title = f"obs dashboard — {os.path.basename(os.path.abspath(obs_dir))}"
    doc = f"""<!doctype html>
<html lang="en"><head><meta charset="utf-8">
<title>{_esc(title)}</title>
<style>{_CSS}</style></head>
<body><div class="wrap">
<h1>{_esc(title)}</h1>
<div class="meta">schema v{meta.get('schema_version', '?')} ·
 codec {_esc(codec)} · {_esc(meta.get('scheme', ''))}
 · J={_esc(meta.get('num_nodes', '?'))}</div>
{''.join(parts)}
<div id="tooltip"></div>
<script type="application/json" id="dash-manifest">
{json.dumps({"series": manifest, "schema_version": schema.SCHEMA_VERSION})}
</script>
<script>{_JS}</script>
</div></body></html>
"""
    out_path = out_path or os.path.join(obs_dir, export_lib.DASHBOARD_FILE)
    with open(out_path, "w") as f:
        f.write(doc)
    return out_path


# ----------------------------------------------------------- self-check ----
def check_dashboard(path: str) -> dict:
    """Verify the rendered HTML delivers everything its manifest promises.

    The manifest is the render's own declaration of which series it chose
    to draw (data-dependent: no node rows -> no heatmaps promised), so
    this check catches a renderer that silently dropped a section, not a
    run that had nothing to show.
    """
    report = {"path": path, "errors": [], "series": []}
    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        report["errors"].append(str(e))
        report["ok"] = False
        return report
    marker = 'id="dash-manifest">'
    at = text.find(marker)
    if at < 0:
        report["errors"].append("no dash-manifest block")
    else:
        end = text.find("</script>", at)
        try:
            manifest = json.loads(text[at + len(marker):end])
        except json.JSONDecodeError as e:
            manifest = {"series": []}
            report["errors"].append(f"manifest unparsable: {e}")
        report["series"] = manifest.get("series", [])
        for sid in report["series"]:
            if f'id="series-{sid}"' not in text:
                report["errors"].append(f"promised series missing: {sid}")
        if manifest.get("schema_version") != schema.SCHEMA_VERSION:
            report["errors"].append(
                f"schema version {manifest.get('schema_version')} != "
                f"{schema.SCHEMA_VERSION}")
    if "<svg" not in text:
        report["errors"].append("no SVG charts rendered")
    report["ok"] = not report["errors"]
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="render an --obs-dir artifact set into one "
                    "self-contained HTML dashboard")
    ap.add_argument("obs_dir", help="ObsWriter output directory")
    ap.add_argument("-o", "--out", default=None,
                    help="output HTML path (default: <obs-dir>/dashboard.html)")
    ap.add_argument("--check", action="store_true",
                    help="after rendering, self-check the HTML (every "
                         "manifest-promised series present); exit 1 on fail")
    args = ap.parse_args(argv)
    path = render_dashboard(args.obs_dir, args.out)
    print(f"dashboard: {path}")
    if args.check:
        report = check_dashboard(path)
        print(json.dumps(report, indent=1, sort_keys=True))
        return 0 if report["ok"] else 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
