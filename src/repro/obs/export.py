"""Exporters: per-run obs artifacts with one emit path for every driver.

``ObsWriter`` owns one run's observability directory (``--obs-dir``):

    run.json              run metadata (schema version, codec, scheme,
                          J, mesh, wire accounting) — written at open
    metrics.jsonl         drained metrics-ring rows, one JSON object per
                          consensus round, keys = ``obs.schema.RING_COLUMNS``
    node_metrics.jsonl    drained node-ring rows (``obs.node_ring``), one
                          JSON object per round: ``{"step", "<metric>":
                          [J values]}``, keys = ``schema.NODE_COLUMNS``
    events.jsonl          the topology event journal (``obs.journal``),
                          plus ``health_*`` events when the writer runs
                          the health monitor (``obs.health``)
    rollup.json           summary rollup written at finalize: convergence
                          curve, active-edge fraction over rounds, wire
                          bytes/round by codec, staleness histogram, host
                          round timing (``round_ms``), per-node health
                          table + advisory recommendations
    roundclock_trace.json Chrome/Perfetto trace of the ``RoundClock``
                          modeled timeline (async runs) — load in
                          https://ui.perfetto.dev to eyeball modeled
                          compute/wire overlap next to a measured
                          ``--profile-rounds`` jax trace

The launcher, the ``AsyncExecutor`` and the benchmark modules all emit
through this one writer instead of bespoke result plumbing, so every run
— training drill, benchmark cell, CI smoke — leaves the same artifact
shapes (validated by ``python -m repro.obs.export --validate DIR``).
``python -m repro.obs.dashboard DIR`` renders the whole set into one
self-contained HTML file.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.obs import node_ring as node_ring_lib
from repro.obs import ring as ring_lib
from repro.obs import schema
from repro.obs.journal import EventJournal

METRICS_FILE = "metrics.jsonl"
NODE_METRICS_FILE = "node_metrics.jsonl"
EVENTS_FILE = "events.jsonl"
ROLLUP_FILE = "rollup.json"
META_FILE = "run.json"
CLOCK_TRACE_FILE = "roundclock_trace.json"
DASHBOARD_FILE = "dashboard.html"


# ------------------------------------------------------------- writer ----
class ObsWriter:
    """One run's observability sink (see module docstring for the layout)."""

    def __init__(self, obs_dir: str, *, meta: dict | None = None,
                 max_staleness: int | None = None,
                 health: bool = False, health_cfg=None):
        self.dir = obs_dir
        os.makedirs(obs_dir, exist_ok=True)
        self.meta = {"schema_version": schema.SCHEMA_VERSION,
                     "ring_columns": list(schema.RING_COLUMNS),
                     "node_columns": list(schema.NODE_COLUMNS),
                     **(meta or {})}
        with open(self._p(META_FILE), "w") as f:
            json.dump(self.meta, f, indent=1, sort_keys=True)
            f.write("\n")
        self._metrics_f = open(self._p(METRICS_FILE), "a")
        # opened lazily on the first node row: a scalar-only run
        # (with_node_ring=False) must not leave an empty node artifact
        self._node_f = None
        self.journal = EventJournal(self._p(EVENTS_FILE),
                                    max_staleness=max_staleness)
        self._rows: list[dict] = []     # in-memory history for the rollup
        self._node_rows: list[dict] = []
        self.dropped_rows = 0
        self.dropped_node_rows = 0
        self._cursor = 0                # metrics-ring drain cursor
        self._node_cursor = 0           # node-ring drain cursor
        # host wall-clock between drains -> the rollup's round_ms (the
        # sync path's ONLY timing source; async runs also have the clock)
        self._drain_log: list[dict] = []
        self._last_drain_t: float | None = None
        self._max_staleness = max_staleness
        # online health monitor: fed per drain, events into the journal
        self._health_on = health or health_cfg is not None
        self._health_cfg = health_cfg
        self.health = None              # built lazily (needs J)
        self._executor_summary: dict | None = None

    def _p(self, name: str) -> str:
        return os.path.join(self.dir, name)

    # ------------------------------------------------------- emit path ----
    def append_metrics(self, rows: list[dict]):
        for r in rows:
            self._metrics_f.write(json.dumps(r) + "\n")
        if rows:
            self._metrics_f.flush()
            self._rows.extend(rows)

    def append_node_metrics(self, rows: list[dict]):
        if rows and self._node_f is None:
            self._node_f = open(self._p(NODE_METRICS_FILE), "a")
        for r in rows:
            self._node_f.write(json.dumps(r) + "\n")
        if rows:
            self._node_f.flush()
            self._node_rows.extend(rows)
        if rows and self._health_on:
            if self.health is None:
                from repro.obs.health import HealthMonitor
                self.health = HealthMonitor(
                    len(rows[0]["r"]), self._health_cfg,
                    journal=self.journal,
                    max_staleness=self._max_staleness)
            self.health.observe_rows(rows)

    def drain(self, state, *, step: int) -> int:
        """One drain: pull both rings + journal the topology. Returns the
        number of new metrics rows. The ONE call every driver makes every
        K rounds — ring rows to ``metrics.jsonl``, node-ring slabs to
        ``node_metrics.jsonl`` (and through the health monitor when on),
        topology/penalty diffs to ``events.jsonl``, overflow and host
        wall-clock accounted for the rollup."""
        now = time.monotonic()
        n = 0
        if getattr(state, "ring", None) is not None:
            rows, self._cursor, dropped = ring_lib.drain_rows(
                state.ring, self._cursor)
            self.dropped_rows += dropped
            self.append_metrics(rows)
            n = len(rows)
        if getattr(state, "node_ring", None) is not None:
            nrows, self._node_cursor, ndropped = \
                node_ring_lib.drain_node_rows(state.node_ring,
                                              self._node_cursor)
            self.dropped_node_rows += ndropped
            self.append_node_metrics(nrows)
        self.journal.observe(state.topo, getattr(state, "penalty", None),
                             step=step)
        # the first drain anchors the clock; each later one records the
        # wall time the n rounds since the previous drain took
        if self._last_drain_t is not None and n > 0:
            self._drain_log.append({
                "step": int(step), "rounds": n,
                "wall_s": now - self._last_drain_t})
        self._last_drain_t = now
        return n

    def observe_executor(self, summary: dict):
        """Feed an ``AsyncExecutor.summary()`` to the health monitor
        (clock-lag straggler path); stored for the rollup either way."""
        self._executor_summary = summary
        if self.health is not None:
            self.health.observe_executor(summary)

    def write_roundclock_trace(self, clock) -> str:
        path = self._p(CLOCK_TRACE_FILE)
        write_roundclock_trace(clock, path)
        return path

    # --------------------------------------------------------- rollup ----
    def finalize(self, extra: dict | None = None) -> dict:
        """Write ``rollup.json`` from the accumulated history and close."""
        rollup = build_rollup(self._rows, meta=self.meta,
                              dropped_rows=self.dropped_rows,
                              journal_events=self.journal.num_events,
                              node_rows=self._node_rows,
                              dropped_node_rows=self.dropped_node_rows,
                              drain_log=self._drain_log)
        if self.health is not None:
            rollup["health"] = {
                **self.health.table(),
                "recommendations": self.health.recommendations(),
            }
        if self._executor_summary is not None:
            rollup["executor"] = self._executor_summary
        if extra:
            rollup.update(extra)
        with open(self._p(ROLLUP_FILE), "w") as f:
            json.dump(rollup, f, indent=1, sort_keys=True)
            f.write("\n")
        self.close()
        return rollup

    def close(self):
        if self._metrics_f is not None:
            self._metrics_f.close()
            self._metrics_f = None
        if self._node_f is not None:
            self._node_f.close()
            self._node_f = None
        self.journal.close()


def build_rollup(rows: list[dict], *, meta: dict | None = None,
                 dropped_rows: int = 0, journal_events: int = 0,
                 node_rows: list[dict] | None = None,
                 dropped_node_rows: int = 0,
                 drain_log: list[dict] | None = None) -> dict:
    """Summary rollup from drained metrics rows (pure, benchmark-friendly)."""
    meta = meta or {}
    node_rows = node_rows or []
    drain_log = drain_log or []

    def curve(key):
        return [r[key] for r in rows]

    ages = [int(r.get("age_max", 0)) for r in rows]
    hist: dict[str, int] = {}
    for a in ages:
        hist[str(a)] = hist.get(str(a), 0) + 1
    stale = [float(r.get("stale_edges", 0.0)) for r in rows]
    # host round timing from the drain wall-clock deltas (the first drain
    # only anchors the clock, so each entry is wall_s over `rounds` rounds)
    round_ms = [1e3 * d["wall_s"] / max(d["rounds"], 1) for d in drain_log]
    per_node: dict = {}
    if node_rows:
        j = len(node_rows[0]["r"])
        per_node = {
            "num_nodes": j,
            "rounds": len(node_rows),
            "dropped_rows": int(dropped_node_rows),
            "r_last": [float(v) for v in node_rows[-1]["r"]],
            "r_mean": [float(np.mean([nr["r"][i] for nr in node_rows]))
                       for i in range(j)],
            "age_mean": [float(np.mean([nr["age_max"][i]
                                        for nr in node_rows]))
                         for i in range(j)],
            "wire_rx_bytes_total": [
                float(np.sum([nr["wire_rx_bytes"][i] for nr in node_rows]))
                for i in range(j)],
        }
    return {
        "schema_version": schema.SCHEMA_VERSION,
        "rounds": len(rows),
        "dropped_rows": int(dropped_rows),
        "journal_events": int(journal_events),
        "steps": curve("step") if rows else [],
        "convergence": {k: curve(k) for k in
                        ("r_max", "s_max", "f_mean")} if rows else {},
        "active_edge_fraction": curve("active_edges") if rows else [],
        "eta_mean": curve("eta_mean") if rows else [],
        "staleness": {
            "age_max_hist": hist,
            "stale_edges_mean": (float(np.mean(stale)) if stale else 0.0),
        },
        "timing": {
            "drains": len(drain_log),
            "round_ms": (float(np.mean(round_ms)) if round_ms else None),
            "round_ms_p50": (float(np.percentile(round_ms, 50))
                             if round_ms else None),
            "round_ms_max": (float(np.max(round_ms)) if round_ms else None),
        },
        "per_node": per_node,
        "wire": {k: meta[k] for k in
                 ("wire_codec", "wire_bytes_per_round", "offsets")
                 if k in meta},
    }


# --------------------------------------------- RoundClock -> Perfetto ----
def roundclock_trace_events(clock) -> list[dict]:
    """Chrome-trace events for the clock's modeled timeline so far.

    Reconstructs the discrete-event model analytically (the clock's stated
    conventions, ``async_exec.clock`` docstring): node i's round k computes
    over ``[k*c_i, (k+1)*c_i)`` (double-buffered permutes hide behind
    compute), and the payload it sends at that round's end is on the wire
    for ``wire_s``. One Perfetto track per node for compute, one for its
    wire, instants for fleet ticks. Times in microseconds (trace units).
    """
    us = 1e6
    ev: list[dict] = []
    compute_s = np.asarray(clock.compute_s, dtype=float)
    j = int(compute_s.shape[0])
    for i in range(j):
        ev.append({"ph": "M", "pid": 0, "tid": i, "name": "thread_name",
                   "args": {"name": f"node {i} compute "
                                    f"({compute_s[i]:g}s/round)"}})
        ev.append({"ph": "M", "pid": 0, "tid": j + i, "name": "thread_name",
                   "args": {"name": f"node {i} wire"}})
        for k in range(int(clock.rounds_done[i])):
            t0 = k * compute_s[i]
            ev.append({"ph": "X", "pid": 0, "tid": i, "cat": "compute",
                       "name": f"round {k}", "ts": t0 * us,
                       "dur": compute_s[i] * us})
            if clock.wire_s > 0:
                ev.append({"ph": "X", "pid": 0, "tid": j + i, "cat": "wire",
                           "name": f"send {k}",
                           "ts": (t0 + compute_s[i]) * us,
                           "dur": clock.wire_s * us})
    tick = getattr(clock, "tick_s", 0.0)
    for t in range(int(clock.ticks)):
        ev.append({"ph": "i", "pid": 0, "tid": 2 * j, "s": "g",
                   "name": f"fleet tick {t + 1}",
                   "ts": (t + 1) * tick * us})
    ev.append({"ph": "M", "pid": 0, "tid": 2 * j, "name": "thread_name",
               "args": {"name": "fleet ticks"}})
    return ev


def write_roundclock_trace(clock, path: str) -> str:
    doc = {"displayTimeUnit": "ms",
           "otherData": {
               "model": "repro.async_exec.clock.RoundClock",
               "sync_round_s": float(clock.sync_round_s),
               "tick_s": float(clock.tick_s),
               "elapsed_s": float(clock.time_s)},
           "traceEvents": roundclock_trace_events(clock)}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f)
        f.write("\n")
    return path


# ---------------------------------------------------------- validation ----
def validate_obs_dir(obs_dir: str) -> dict:
    """Well-formedness report for one obs directory (CI's artifact gate).

    Checks every present artifact parses as (JSONL-)JSON and that metrics
    rows carry the full schema key set. Missing optional artifacts
    (roundclock trace on sync runs) are reported, not failed; a missing
    metrics/rollup file IS a failure — every ``--obs-dir`` run must leave
    them.
    """
    report = {"dir": obs_dir, "files": {}, "errors": []}

    def err(msg):
        report["errors"].append(msg)

    for name, required in ((META_FILE, True), (METRICS_FILE, True),
                           (NODE_METRICS_FILE, False),
                           (EVENTS_FILE, True), (ROLLUP_FILE, True),
                           (CLOCK_TRACE_FILE, False),
                           (DASHBOARD_FILE, False)):
        path = os.path.join(obs_dir, name)
        info = {"present": os.path.exists(path)}
        report["files"][name] = info
        if not info["present"]:
            if required:
                err(f"{name}: missing")
            continue
        try:
            with open(path) as f:
                if name.endswith(".jsonl"):
                    rows = [json.loads(ln) for ln in f if ln.strip()]
                    info["rows"] = len(rows)
                    if name == METRICS_FILE:
                        want = set(schema.RING_COLUMNS)
                        for i, r in enumerate(rows):
                            missing = want - set(r)
                            if missing:
                                err(f"{name}:{i}: missing keys "
                                    f"{sorted(missing)}")
                                break
                    if name == NODE_METRICS_FILE:
                        want = set(schema.NODE_COLUMNS)
                        for i, r in enumerate(rows):
                            missing = want - set(r)
                            if missing:
                                err(f"{name}:{i}: missing keys "
                                    f"{sorted(missing)}")
                                break
                elif name == DASHBOARD_FILE:
                    pass  # HTML; checked by `-m repro.obs.dashboard --check`
                else:
                    doc = json.load(f)
                    if name == ROLLUP_FILE:
                        for k in ("rounds", "convergence", "staleness",
                                  "timing"):
                            if k not in doc:
                                err(f"{name}: missing key {k!r}")
                    if name == CLOCK_TRACE_FILE and "traceEvents" not in doc:
                        err(f"{name}: no traceEvents")
        except (json.JSONDecodeError, OSError) as e:
            err(f"{name}: {e}")
    report["ok"] = not report["errors"]
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate an --obs-dir artifact set")
    ap.add_argument("--validate", required=True, metavar="DIR",
                    help="obs directory to check for well-formed artifacts")
    args = ap.parse_args(argv)
    report = validate_obs_dir(args.validate)
    print(json.dumps(report, indent=1, sort_keys=True))
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
