"""Trace spans for the consensus round — one naming convention, two layers.

Two span kinds, matching where the code runs:

  * ``span(name)`` — TRACED code (inside jit): a ``jax.named_scope``. The
    scope name lands in the lowered HLO op metadata, so a jax profiler
    trace (``--profile-rounds``) groups the round's ops under readable
    phases instead of a flat op soup. Zero runtime cost — metadata only.
  * ``host_span(name)`` — HOST code (the executor/launcher round loop): a
    ``jax.profiler.TraceAnnotation``, visible on the python thread track
    of the same profile.

Span naming convention (documented in ``docs/observability.md``, consumed
by trace viewers as a hierarchy on ``/``):

    consensus/pack            flat-buffer pack + wire encode
    consensus/exchange/off<k> one graph offset's collective-permute+decode
    consensus/probe           objective probes f_i(theta_j)
    consensus/fused_round     the fused Pallas call (+ residual psum)
    consensus/penalty         penalty + topology update
    wire/encode  wire/decode  codec work inside the phases above
    round/sync  round/async   host-side whole-round annotations

Spans are built through ``span_factory(enabled)`` so the obs-off path gets
``nullcontext`` factories — with observability disabled the lowered HLO is
byte-identical to pre-obs code (named_scope changes metadata, which IS
part of the lowered text, so it must be gated too; pinned in
``tests/test_obs.py``).
"""
from __future__ import annotations

import contextlib

import jax


def span(name: str):
    """Named scope for traced code; nests under the active scope."""
    return jax.named_scope(name)


def host_span(name: str):
    """Profiler annotation for host-side code (python thread track)."""
    try:
        return jax.profiler.TraceAnnotation(name)
    except Exception:  # pragma: no cover — profiler unavailable
        return contextlib.nullcontext()


def _null_span(name: str):
    return contextlib.nullcontext()


def span_factory(enabled: bool):
    """Returns the traced-span factory: ``span`` when on, nullcontext off."""
    return span if enabled else _null_span


def host_span_factory(enabled: bool):
    return host_span if enabled else _null_span
