"""Online health monitor: the layer that INTERPRETS per-node telemetry.

The node ring (``obs.node_ring``) records what each node did; this module
decides what it means. A ``HealthMonitor`` consumes drained per-node rows
(``schema.node_row_to_dict`` dicts — the same records ``ObsWriter`` spools
to ``node_metrics.jsonl``) plus, optionally, the async executor's clock
summary, and runs a bank of four deterministic detectors:

  * **divergence** — windowed growth of a node's primal residual ``r_i``:
    the second half of the window persistently above ``divergence_ratio``
    x the first half. Temporal: "this node is getting WORSE".
  * **eta stall / oscillation** — is the paper's adaptation (eq. 7-9)
    still doing anything for this node? Stall fires when the node's
    ``eta_row_mean`` is frozen across the window while its residual is
    still material (adaptation gave up early); oscillation fires when the
    per-round deltas keep flipping sign at material amplitude (the
    flapping mode the scheme's monotone budget is supposed to preclude).
  * **straggler** — staleness ages from the rows (mean incident age vs the
    bound) and, when an executor summary is supplied, RoundClock lag
    percentiles (rounds behind the fleet front-runner).
  * **drift** — cross-sectional outlier: a node whose residual sits
    persistently above ``drift_ratio`` x the fleet median of the same
    round. Unlike divergence this needs no growth — a node stuck far from
    consensus while everyone else converged drifts without diverging.

Detectors fire on the TRANSITION into the bad state (one ``health_*``
event per episode, re-armed when the node recovers), so a journal stays
readable; the current boolean state lives in the per-node score table.
Everything is a pure function of the observed series — no wall clock, no
randomness — which is what makes the synthetic-trace unit tests exact.

Events ride the existing ``EventJournal`` JSONL (``journal.emit``), the
score table and the advisory ``recommendations`` block land in the
ObsWriter rollup, and ``launch/train.py --health`` prints both. The
recommendations are ADVISORY ONLY — nothing in the trainer acts on them
(that is the ROADMAP's elastic/autoscaler item, which needs exactly these
signals).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

# the event names this module can emit (the dashboard and the tests key
# off this registry; append-only like the schema column registries)
HEALTH_EVENTS = (
    "health_divergence",
    "health_eta_stall",
    "health_eta_oscillation",
    "health_straggler",
    "health_drift",
)

# score deductions per active detector state (clamped to [0, 1]); the
# weights order the failure modes by how actionable they are: a diverging
# node poisons its neighbors' consensus pulls, a straggler only slows them
_WEIGHTS = {
    "divergence": 0.5,
    "eta_stall": 0.2,
    "eta_oscillation": 0.2,
    "straggler": 0.3,
    "drift": 0.4,
}


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Detector thresholds (all pure, all deterministic).

    Attributes:
      window: rows of per-node history each detector looks at. Detectors
        are silent until the window fills.
      divergence_ratio: fire divergence when mean(r_i over the window's
        second half) > ratio x mean(first half).
      min_residual: residuals below this are "converged" — no divergence,
        stall or drift verdicts are rendered on noise-floor values.
      stall_tol: max |delta eta_row_mean| over the window still counting
        as frozen (relative to the window's mean level).
      osc_flip_frac: fraction of consecutive delta-sign flips (among
        material deltas) above which eta is oscillating.
      drift_ratio: fire drift when r_i > ratio x fleet median for every
        row in the window.
      straggler_age_frac: fire straggler when the node's mean incident
        staleness age exceeds this fraction of ``max_staleness``.
      straggler_lag: fire straggler when the clock lag (rounds behind the
        fleet front-runner) reaches this many rounds.
      drop_score: score threshold under which a node becomes a
        drop-candidate in the recommendations block.
    """

    window: int = 8
    divergence_ratio: float = 2.0
    min_residual: float = 1e-6
    stall_tol: float = 1e-3
    osc_flip_frac: float = 0.6
    drift_ratio: float = 4.0
    straggler_age_frac: float = 0.5
    straggler_lag: int = 4
    drop_score: float = 0.5

    def __post_init__(self):
        if self.window < 2:
            raise ValueError(f"window {self.window} < 2")


class HealthMonitor:
    """Stateful detector bank over a stream of drained node rows.

    Args:
      cfg: detector thresholds.
      num_nodes: fleet size J (row vectors are validated against it).
      journal: optional ``obs.journal.EventJournal`` — fired events are
        ``emit``-ted there as well as returned.
      max_staleness: the async bound (enables the age-based straggler
        path; sync traces leave it None and ages are all zero anyway).
    """

    def __init__(self, num_nodes: int, cfg: HealthConfig | None = None, *,
                 journal=None, max_staleness: int | None = None):
        self.cfg = cfg or HealthConfig()
        self.num_nodes = int(num_nodes)
        self.journal = journal
        self.max_staleness = max_staleness
        w = self.cfg.window
        self._r = [deque(maxlen=w) for _ in range(num_nodes)]
        self._eta = [deque(maxlen=w) for _ in range(num_nodes)]
        self._age = [deque(maxlen=w) for _ in range(num_nodes)]
        self._r_med = deque(maxlen=w)        # fleet median per row
        self._state = {name: [False] * num_nodes
                       for name in _WEIGHTS}  # current boolean verdicts
        self._fires = {name: [0] * num_nodes for name in _WEIGHTS}
        self._lag = [0] * num_nodes           # latest executor lag
        self._last_step = 0
        self.num_rows = 0

    # ------------------------------------------------------ ingestion ----
    def observe_rows(self, node_rows: list[dict]) -> list[dict]:
        """Feed drained per-node rows (chronological); returns new events."""
        events: list[dict] = []
        for row in node_rows:
            events.extend(self._observe_row(row))
        return events

    def _observe_row(self, row: dict) -> list[dict]:
        j = self.num_nodes
        r = [float(v) for v in row["r"]]
        if len(r) != j:
            raise ValueError(f"row has {len(r)} nodes, monitor built "
                             f"for {j}")
        eta = [float(v) for v in row["eta_row_mean"]]
        age = [int(v) for v in row["age_max"]]
        alive = [bool(v) for v in row.get("alive", [1.0] * j)]
        self._last_step = step = int(row["step"])
        live_r = [ri for ri, a in zip(r, alive) if a]
        self._r_med.append(float(np.median(live_r)) if live_r else 0.0)
        for i in range(j):
            self._r[i].append(r[i])
            self._eta[i].append(eta[i])
            self._age[i].append(age[i])
        self.num_rows += 1

        events: list[dict] = []
        for i in range(j):
            if not alive[i]:
                # ghost rows carry stale values; clear their verdicts
                for name in _WEIGHTS:
                    self._state[name][i] = False
                continue
            events.extend(self._judge(i, step))
        return events

    def observe_executor(self, summary: dict) -> list[dict]:
        """Feed an ``AsyncExecutor.summary()`` dict (clock lag path).

        Raise-only: a lag above the threshold flags the node, but a low
        lag never CLEARS a straggler verdict — the per-row age path owns
        recovery (the two paths share one state, and a summary snapshot
        must not erase what the age distribution is still showing).
        """
        lag = summary.get("round_lag")
        if lag is None:
            return []
        self._lag = [int(v) for v in lag]
        events: list[dict] = []
        for i, l in enumerate(self._lag):
            if l >= self.cfg.straggler_lag:
                events.extend(self._transition(
                    "straggler", i, True, self._last_step, lag=l))
        return events

    # ------------------------------------------------------- detectors ----
    def _judge(self, i: int, step: int) -> list[dict]:
        cfg = self.cfg
        events: list[dict] = []
        r = np.asarray(self._r[i], dtype=np.float64)
        full = len(r) >= cfg.window

        # divergence: second half of the window grew past ratio x first
        if full:
            half = cfg.window // 2
            lo, hi = float(r[:half].mean()), float(r[half:].mean())
            verdict = (hi > cfg.min_residual
                       and hi > cfg.divergence_ratio * max(lo,
                                                           cfg.min_residual))
            events.extend(self._transition(
                "divergence", i, verdict, step,
                r_early=lo, r_late=hi))

        # eta stall / oscillation
        if full:
            eta = np.asarray(self._eta[i], dtype=np.float64)
            deltas = np.diff(eta)
            level = max(float(np.abs(eta).mean()), 1e-12)
            material = np.abs(deltas) > cfg.stall_tol * level
            frozen = not material.any()
            resid = float(r[-1])
            stall = frozen and resid > cfg.min_residual
            events.extend(self._transition(
                "eta_stall", i, stall, step,
                eta=float(eta[-1]), r=resid))
            osc = False
            if material.sum() >= 2:
                signs = np.sign(deltas[material])
                flips = float((signs[1:] != signs[:-1]).mean())
                osc = flips >= cfg.osc_flip_frac
            events.extend(self._transition(
                "eta_oscillation", i, osc, step, eta=float(eta[-1])))

        # straggler (age path; the lag path is observe_executor)
        if full and self.max_staleness is not None and self.max_staleness > 0:
            mean_age = float(np.mean(self._age[i]))
            verdict = mean_age > cfg.straggler_age_frac * self.max_staleness
            events.extend(self._transition(
                "straggler", i, verdict, step, mean_age=mean_age))

        # drift: persistently far above the fleet median
        if full and len(self._r_med) >= cfg.window:
            med = np.asarray(self._r_med, dtype=np.float64)
            above = r > np.maximum(cfg.drift_ratio * med, cfg.min_residual)
            verdict = bool(above.all()) and float(r[-1]) > cfg.min_residual
            events.extend(self._transition(
                "drift", i, verdict, step,
                r=float(r[-1]), fleet_median=float(med[-1])))
        return events

    def _transition(self, name: str, i: int, verdict: bool, step: int,
                    **detail) -> list[dict]:
        """Edge-triggered state machine: one event per episode."""
        was = self._state[name][i]
        self._state[name][i] = verdict
        if verdict and not was:
            self._fires[name][i] += 1
            ev = {"step": int(step), "event": f"health_{name}",
                  "node": int(i), **detail}
            if self.journal is not None:
                self.journal.emit(ev)
            return [ev]
        return []

    # --------------------------------------------------------- outputs ----
    def scores(self) -> list[float]:
        """Per-node health in [0, 1]: 1 minus the active-state deductions."""
        out = []
        for i in range(self.num_nodes):
            s = 1.0 - sum(w for name, w in _WEIGHTS.items()
                          if self._state[name][i])
            out.append(round(max(0.0, s), 4))
        return out

    def table(self) -> dict:
        """The rollup's per-node health table (JSON-ready)."""
        scores = self.scores()
        nodes = []
        for i in range(self.num_nodes):
            nodes.append({
                "node": i,
                "score": scores[i],
                **{name: bool(self._state[name][i]) for name in _WEIGHTS},
                "fires": {name: self._fires[name][i] for name in _WEIGHTS
                          if self._fires[name][i]},
                "lag": self._lag[i],
            })
        return {"rows_seen": self.num_rows, "last_step": self._last_step,
                "window": self.cfg.window, "nodes": nodes}

    def recommendations(self) -> dict:
        """Advisory block: printed by ``--health``, never acted on."""
        cfg = self.cfg
        scores = self.scores()
        drop = [i for i, s in enumerate(scores)
                if s < cfg.drop_score
                and (self._state["divergence"][i]
                     or self._state["drift"][i]
                     or self._state["straggler"][i])]
        # a stalled eta with material residual is exactly what the
        # paper's eq. (10) budget top-up exists to fix
        topup = [i for i in range(self.num_nodes)
                 if self._state["eta_stall"][i]]
        notes = []
        for i in drop:
            active = [n for n in _WEIGHTS if self._state[n][i]]
            notes.append(f"node {i}: score {scores[i]} "
                         f"({', '.join(active)}) — drop candidate")
        for i in topup:
            notes.append(f"node {i}: eta stalled with residual above "
                         f"floor — raise its budget (eq. 10 top-up)")
        return {"drop_candidates": drop, "budget_topup": topup,
                "notes": notes}


def analyze_trace(node_rows: list[dict], num_nodes: int, *,
                  cfg: HealthConfig | None = None,
                  executor_summary: dict | None = None,
                  journal=None, max_staleness: int | None = None) -> dict:
    """One-shot convenience: run a fresh monitor over a full trace.

    Returns ``{"events", "table", "recommendations"}`` — what the
    ObsWriter folds into the rollup and the dashboard annotates.
    """
    mon = HealthMonitor(num_nodes, cfg, journal=journal,
                        max_staleness=max_staleness)
    events = mon.observe_rows(node_rows)
    if executor_summary is not None:
        events += mon.observe_executor(executor_summary)
    return {"events": events, "table": mon.table(),
            "recommendations": mon.recommendations()}
